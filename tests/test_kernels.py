"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.blocksparse import random_bsr
from repro.core.interact import spmv_bsr_ml_batched
from repro.kernels import ops, ref
from repro.kernels.block_attention import block_attention as ba_kernel
from repro.kernels.bsr_spmv import bsr_spmv as bsr_kernel
from repro.kernels.bsr_spmv import bsr_spmv_batched as batch_kernel
from repro.kernels.gamma_score import gamma_pairs


@pytest.mark.parametrize("n,bs,nbr,f", [
    (256, 16, 3, 1), (512, 32, 5, 4), (512, 64, 2, 8), (256, 128, 2, 2),
])
def test_bsr_spmv_shapes(n, bs, nbr, f):
    bsr = random_bsr(n * bs, n, bs, nbr)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    pad = bsr.n_rb * bs - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    got = bsr_kernel(bsr.vals, bsr.col_idx, xp, interpret=True)
    want = ref.bsr_spmv_ref(bsr.vals, bsr.col_idx, xp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spmv_dtypes(dtype):
    bsr = random_bsr(11, 256, 32, 4)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 2)), jnp.float32).astype(dtype)
    got = ops.bsr_spmv(bsr.vals, bsr.col_idx, x, 256)
    want = ref.bsr_spmv_ref(bsr.vals, bsr.col_idx,
                            x.astype(jnp.float32))[:256]
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,dh,bq,bk,nsel,causal", [
    (128, 16, 16, 16, 3, True),
    (256, 32, 32, 32, 4, True),
    (256, 64, 64, 32, 2, False),
    (128, 32, 16, 32, 4, True),
])
def test_block_attention_shapes(S, dh, bq, bk, nsel, causal):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((S, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((S, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((S, dh)), jnp.float32)
    kpos = jnp.asarray(rng.permutation(S), jnp.int32)
    qpos = jnp.arange(S, dtype=jnp.int32)
    idx = jnp.asarray(rng.integers(0, S // bk, (S // bq, nsel)), jnp.int32)
    got = ba_kernel(q, k, v, kpos, qpos, idx, bq=bq, bk=bk, causal=causal,
                    interpret=True)
    want = ref.block_attention_ref(q, k, v, kpos, qpos, idx, bq=bq, bk=bk,
                                   causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_block_attention_batched_wrapper_matches_core():
    """ops.block_attention (vmapped kernel) == core.clusterkv reference."""
    from repro.core import clusterkv as ckv
    rng = np.random.default_rng(3)
    B, Hq, Hkv, S, dh, bq, bk, nsel = 2, 4, 2, 128, 16, 32, 32, 3
    q = jnp.asarray(rng.standard_normal((B, Hq, S, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, dh)), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, Hkv, S))
    qpos = jnp.arange(S, dtype=jnp.int32)
    idx = jnp.asarray(rng.integers(0, S // bk, (B, Hkv, S // bq, nsel)),
                      jnp.int32)
    got = ops.block_attention(q, k, v, kpos, qpos, idx, bq=bq, bk=bk)
    want = ckv.sparse_block_attention(q, k, v, kpos, qpos, idx, bq, bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nnz,bn", [(128, 64), (300, 128), (512, 256)])
def test_gamma_pairs_shapes(nnz, bn):
    rng = np.random.default_rng(4)
    coords = jnp.asarray(rng.integers(0, 100, (nnz, 2)), jnp.float32)
    pad = (-nnz) % bn
    if pad:
        far = jnp.full((pad, 2), 1e9) + jnp.arange(pad)[:, None] * 1e6
        padded = jnp.concatenate([coords, far.astype(jnp.float32)])
    else:
        padded = coords
    got = float(gamma_pairs(padded, 7.0, bn, interpret=True)) - pad
    want = float(ref.gamma_pairs_ref(coords, 7.0))
    assert got == pytest.approx(want, rel=1e-4)


# -- batch-grid kernel: edge shapes, all bit-matching bsr_ml batched --------


def _random_batch(B, n_cb, bs, nbr, seed=0):
    vals, idxs = [], []
    for b in range(B):
        bsr = random_bsr(seed + b, n_cb * bs, bs, nbr)
        vals.append(np.asarray(bsr.vals))
        idxs.append(np.asarray(bsr.col_idx))
    return (jnp.asarray(np.stack(vals), jnp.float32),
            jnp.asarray(np.stack(idxs), jnp.int32))


@pytest.mark.parametrize("B,n_cb,bs,nbr,f,rbs,fc", [
    (1, 8, 16, 4, 1, 1, None),     # degenerate single member
    (3, 8, 16, 4, 1, 4, None),     # row-superblocked, scalar charges
    (3, 8, 16, 4, 3, 2, 2),        # f not a multiple of the feature tile
    (2, 8, 16, 4, 5, 3, 4),        # rbs not dividing n_rb (row padding)
])
def test_batch_kernel_bit_matches_bsr_ml(B, n_cb, bs, nbr, f, rbs, fc):
    vals, col_idx = _random_batch(B, n_cb, bs, nbr)
    rng = np.random.default_rng(9)
    shape = (B, n_cb * bs) if f == 1 else (B, n_cb * bs, f)
    xs = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    got = batch_kernel(vals, col_idx, xs, rbs=rbs, fc=fc, interpret=True)
    want = spmv_bsr_ml_batched(vals, col_idx, xs, 8)
    assert got.dtype == want.dtype and got.shape == want.shape
    assert bool(jnp.array_equal(got, want))      # bitwise, not approx


def _holey_batch():
    """Pow2-padded capacity with interleaved streaming holes and ELL
    padding slots (ell_slack widens max_nbr beyond the live columns)."""
    rng = np.random.default_rng(3)
    xs = [rng.standard_normal((120, 8)).astype(np.float32)
          for _ in range(3)]
    pb = api.build_plan_batch(xs, k=8, bs=16, sb=4, backend="bsr",
                              ell_slack=4, capacity=128)
    kills = [rng.choice(120, 17, replace=False) for _ in range(3)]
    return pb.delete(kills)


def test_batch_backend_holes_and_padding_bit_match():
    pb = _holey_batch()
    rng = np.random.default_rng(4)
    for shape in [(pb.batch, pb.capacity), (pb.batch, pb.capacity, 3)]:
        xs = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        want = api._batch_apply_kernel(pb.spec, pb.data, xs, "bsr_ml",
                                       "apply")
        got = api._batch_apply_kernel(pb.spec, pb.data, xs, "pallas",
                                      "apply")
        assert bool(jnp.array_equal(got, want))


def test_single_plan_pallas_dead_slots_stay_zero():
    """The pallas single-plan backend handles capacity-padded plans with
    streaming holes: dead-slot rows carry zero tiles, so their output rows
    must be exactly zero (and live rows must match the bsr path)."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((120, 8)).astype(np.float32)
    plan = api.build_plan(jnp.asarray(x), k=8, bs=16, sb=4, backend="bsr",
                          capacity=128)
    plan = plan.delete(rng.choice(120, 13, replace=False))
    for shape in [(plan.n,), (plan.n, 4)]:
        q = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        y_pl = np.asarray(plan.apply(q, backend="pallas"))
        y_ref = np.asarray(plan.apply(q, backend="bsr"))
        np.testing.assert_allclose(y_pl, y_ref, rtol=1e-5, atol=1e-5)
        dead = ~plan.permute(plan.alive)
        assert dead.any()
        assert not np.any(y_pl[dead])            # exactly zero, no residue


def test_batched_pallas_64_members_one_kernel():
    """64-member PlanBatch matvec: ONE compiled kernel (trace-counted) and
    bit-identical to the bsr_ml batched backend."""
    rng = np.random.default_rng(6)
    xs = [rng.standard_normal((64, 8)).astype(np.float32)
          for _ in range(64)]
    pb = api.build_plan_batch(xs, k=6, bs=16, sb=4, backend="bsr")
    x = jnp.asarray(rng.standard_normal((64, pb.capacity)), jnp.float32)
    ops.PALLAS_TRACE_COUNTS["batched"] = 0
    got = pb.matvec(x, backend="pallas")
    for _ in range(2):                           # re-dispatch, no re-trace
        got = pb.matvec(x, backend="pallas")
    assert ops.PALLAS_TRACE_COUNTS["batched"] == 1
    want = pb.matvec(x, backend="bsr_ml")
    assert bool(jnp.array_equal(got, want))


@pytest.mark.parametrize("n,bs,k,d", [(256, 16, 6, 2), (512, 32, 10, 3)])
def test_tsne_force_kernel(n, bs, k, d):
    """Kernel vs jnp oracle vs core.interact blockwise path."""
    from repro.core import blocksparse, interact
    rng = np.random.default_rng(0)
    rows = np.repeat(np.arange(n), k)
    cols = rng.integers(0, n, n * k)
    key = rows.astype(np.int64) * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    pv = rng.random(len(rows)).astype(np.float32)
    bsr = blocksparse.build_bsr(rows, cols, pv, n, bs=bs)
    y = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    got = ops.tsne_force(bsr.vals, bsr.col_idx, y, n)
    want_core = interact.tsne_attractive(bsr.vals, bsr.col_idx,
                                         bsr.nbr_mask, y, n)
    yp = jnp.pad(y, ((0, bsr.n_rb * bs - n), (0, 0)))
    want_ref = ref.tsne_force_ref(bsr.vals, bsr.col_idx, yp)[:n]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_core),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused decode attention (kernels/decode_attend.py)
# ---------------------------------------------------------------------------
#
# The references are the JITTED pure-JAX ops: the decode service calls them
# inside the engine's jitted tick, and on XLA:CPU an eagerly-executed dot
# can round differently from its jitted fusion — jit is the contract.


def _plain_decode_case(seed, B, hq, hkv, S, dh, bk, dtype):
    from repro.core import clusterkv as ckv
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, hkv, S, dh)),
                    jnp.float32).astype(dtype)
    v = jnp.asarray(rng.standard_normal((B, hkv, S, dh)),
                    jnp.float32).astype(dtype)
    pos = jnp.asarray(np.stack([np.stack([rng.permutation(S)
                                          for _ in range(hkv)])
                                for _ in range(B)]), jnp.int32)
    cent = ckv.block_centroids(k, bk)
    return q, k, v, pos, cent


@pytest.mark.parametrize("hq,hkv,dtype", [
    (1, 1, jnp.float32),           # g == 1: the strength-reduction trap
    (4, 2, jnp.float32),
    (8, 2, jnp.float32),
    (4, 4, jnp.bfloat16),          # g == 1 again, bf16 cache
    (6, 2, jnp.bfloat16),
])
def test_decode_fused_bitwise_plain(hq, hkv, dtype):
    """Fused kernel == jitted decode_select + decode_attend, bitwise."""
    from repro.core import clusterkv as ckv
    S, dh, bk, n_sel = 128, 32, 32, 2
    q, k, v, pos, cent = _plain_decode_case(11, 2, hq, hkv, S, dh, bk,
                                            dtype)
    for qpos in (S - 1, S // 3):
        got = ops.decode_attend_fused(q, k, v, pos, cent, qpos,
                                      n_sel=n_sel, bk=bk)
        idx = ckv.decode_select(q, cent.astype(jnp.float32), n_sel)
        want = ckv.decode_attend(q, k, v, pos, qpos, idx, bk)
        assert got.dtype == want.dtype
        assert bool(jnp.array_equal(got, want)), qpos


@pytest.mark.parametrize("hq,hkv,has_self", [
    (2, 2, True),                  # g == 1
    (2, 2, False),
    (4, 2, True),
    (8, 2, False),
    (8, 1, True),
])
def test_decode_fused_bitwise_plan_holey(hq, hkv, has_self):
    """Plan mode vs the jitted xla decode backend over capacity-padded
    caches: hole slots (pos == INT32_MAX) carry garbage k/v and must be
    bitwise-invisible; the self column must ride along untouched."""
    import functools

    from repro.configs.base import ClusterKVConfig
    from repro.models import attention as attn

    B, S, dh, bk = 3, 128, 32, 32
    cfg = ClusterKVConfig(enabled=True, block_k=bk, decode_clusters=2,
                          decode_backend="pallas")
    rng = np.random.default_rng(13)
    big = np.iinfo(np.int32).max
    q = jnp.asarray(rng.standard_normal((B, hq, dh)), jnp.bfloat16)
    ks = jnp.asarray(rng.standard_normal((B, hkv, S, dh)), jnp.bfloat16)
    vs = jnp.asarray(rng.standard_normal((B, hkv, S, dh)), jnp.bfloat16)
    qpos = jnp.asarray(rng.integers(8, 96, (B,)), jnp.int32)
    ps = np.full((B, hkv, S), big, np.int64)
    for b in range(B):
        live = int(qpos[b])                  # plan rows streamed so far
        for h in range(hkv):
            rows = rng.choice(S, live, replace=False)
            ps[b, h, rows] = rng.permutation(live)
    ps = jnp.asarray(ps, jnp.int32)
    from repro.core import clusterkv as ckv
    cent = ckv.block_centroids(ks.astype(jnp.float32), bk)
    k_self = jnp.asarray(rng.standard_normal((B, hkv, dh)), jnp.bfloat16)
    v_self = jnp.asarray(rng.standard_normal((B, hkv, dh)), jnp.bfloat16)

    ref = jax.jit(functools.partial(attn._plan_decode_xla, cfg=cfg))
    if has_self:
        want = ref(q, ks, vs, ps, cent, qpos, k_self=k_self, v_self=v_self)
        got = attn.clusterkv_plan_decode(q, ks, vs, ps, cent, qpos, cfg,
                                         k_self=k_self, v_self=v_self)
    else:
        want = ref(q, ks, vs, ps, cent, qpos)
        got = attn.clusterkv_plan_decode(q, ks, vs, ps, cent, qpos, cfg)
    assert got.dtype == want.dtype
    assert bool(jnp.array_equal(got, want))


def test_decode_fused_one_trace():
    """Re-dispatching the fused decode at a fixed shape must not re-trace
    (the serve tick calls it every token)."""
    q, k, v, pos, cent = _plain_decode_case(17, 2, 4, 2, 128, 32, 32,
                                            jnp.float32)

    @jax.jit
    def tick(q, k, v, pos, cent, qpos):
        return ops.decode_attend_fused(q, k, v, pos, cent, qpos,
                                       n_sel=2, bk=32)

    ops.PALLAS_TRACE_COUNTS["decode"] = 0
    for qpos in (40, 50, 60):                # dynamic arg, same shape
        tick(q, k, v, pos, cent, jnp.full((2,), qpos, jnp.int32)
             ).block_until_ready()
    assert ops.PALLAS_TRACE_COUNTS["decode"] == 1


def test_decode_fused_rejects_ragged_cache():
    q, k, v, pos, cent = _plain_decode_case(19, 1, 2, 1, 128, 32, 32,
                                            jnp.float32)
    with pytest.raises(ValueError, match="whole"):
        ops.decode_attend_fused(q, k[:, :, :100], v[:, :, :100],
                                pos[:, :, :100], cent, 99, n_sel=2, bk=32)
