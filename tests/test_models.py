"""Per-arch smoke tests (reduced configs, deliverable f) + decode/forward
consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import model_api
from repro.models.sharding import NO_SHARD


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params, specs = model_api.init(cfg, key)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
    mod = model_api.module_for(cfg)
    batch = model_api.make_small_batch(cfg, key, batch=2, seq=64, kind="train")
    loss = mod.loss_fn(params, cfg, batch, NO_SHARD, "dense")
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # one grad step moves the loss
    g = jax.grad(lambda p: mod.loss_fn(p, cfg, batch, NO_SHARD, "dense"))(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params, _ = model_api.init(cfg, key)
    mod = model_api.module_for(cfg)
    batch = model_api.make_small_batch(cfg, key, batch=2, seq=64,
                                       kind="prefill")
    cache, logits = mod.prefill(params, cfg, batch, NO_SHARD, "dense")
    assert logits.shape == (2, cfg.vocab)
    if cfg.family == "vlm":
        tok = jax.random.normal(key, (2, 1, cfg.d_model)).astype(jnp.bfloat16)
    else:
        tok = jnp.zeros((2, 1), jnp.int32)
    lg, cache2 = mod.decode_step(params, cfg, cache, tok, NO_SHARD, "dense")
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "minicpm3-4b",
                                  "falcon-mamba-7b", "whisper-medium"])
def test_decode_matches_prefill_f32(arch):
    """Teacher forcing in f32: prefill(S) last logits == prefill(S-1) +
    one decode step of the final token."""
    cfg = reduced_config(arch).with_(dtype="float32", remat=False)
    key = jax.random.PRNGKey(2)
    params, _ = model_api.init(cfg, key)
    mod = model_api.module_for(cfg)
    S = 32
    batch = model_api.make_small_batch(cfg, key, batch=2, seq=S,
                                       kind="prefill")
    full_cache, full_logits = mod.prefill(params, cfg, batch, NO_SHARD,
                                          "dense")
    # drop last token, decode it
    short = {k: (v[:, :S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
             for k, v in batch.items()}
    if cfg.family == "encdec":
        short["frames"] = batch["frames"]        # enc input stays full
    cache, _ = mod.prefill(params, cfg, short, NO_SHARD, "dense")
    # grow cache along seq by 1 where needed
    def grow(x):
        if x.ndim >= 3 and (S - 1) in x.shape:
            ax = list(x.shape).index(S - 1)
            pads = [(0, 0)] * x.ndim
            pads[ax] = (0, 1)
            return jnp.pad(x, pads)
        return x
    cache = jax.tree.map(grow, cache)
    tok = batch["tokens"][:, S - 1:S] if "tokens" in batch else None
    lg, _ = mod.decode_step(params, cfg, cache, tok, NO_SHARD, "dense")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_shapes_and_balance():
    from repro.models import moe as moe_mod
    cfg = reduced_config("granite-moe-3b-a800m")
    key = jax.random.PRNGKey(3)
    p, s = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = moe_mod.moe_ffn(p, x, cfg, NO_SHARD)
    assert y.shape == x.shape
    assert float(aux) > 0


def test_param_counts_full_configs():
    """Full-config param counts via eval_shape (no allocation)."""
    import math
    expect = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "mistral-large-123b": (110e9, 135e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "llama4-maverick-400b-a17b": (350e9, 900e9),
        "minicpm3-4b": (3e9, 6e9),
        "zamba2-1.2b": (0.9e9, 1.9e9),
    }
    from repro.configs import get_config
    for arch, (lo, hi) in expect.items():
        shapes = model_api.param_shapes(get_config(arch))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert lo < n < hi, (arch, n)
