"""Streaming point sets (ISSUE 4): capacity vs logical n, insert/delete
tombstones, amortized compaction, placement, sharded composition, and
checkpoint round-trip of the capacity/tombstone state."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint.ckpt import Checkpointer
from repro.core import blocksparse, hierarchy, measures
from repro.core.doublebuf import DoubleBufferedPlan
from repro.core.ordering import claim_free_slots
from repro.data.pipeline import feature_mixture

N, D, K = 512, 32, 8


@pytest.fixture(scope="module")
def points():
    return feature_mixture(N, D, n_clusters=8, seed=0)


@pytest.fixture(scope="module")
def plan(points):
    return api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8)


def _fresh_points(m, seed):
    return feature_mixture(max(m, 8), D, n_clusters=8, seed=seed)[:m]


def _masked_dense_matvec(plan, xv):
    """Reference: y = A x off the stored tiles, original order."""
    a = plan.bsr.to_dense()
    yc = a @ np.asarray(xv)[plan.host.pi]
    return yc[plan.host.inv]


# ---------------------------------------------------------------------------
# delete: tombstones
# ---------------------------------------------------------------------------


def test_delete_tombstones_rows_and_columns(plan):
    rng = np.random.default_rng(1)
    kill = rng.choice(N, 25, replace=False)
    p2 = plan.delete(kill)
    assert p2.n_alive == N - 25 and p2.capacity == N
    assert not p2.alive[kill].any() and p2.dead_frac > 0
    st = p2.refresh_stats
    assert st.last_action == "tombstone" and st.tombstones == 1
    assert st.deleted_total == 25

    # permutation untouched; input plan not mutated
    np.testing.assert_array_equal(p2.host.pi, plan.host.pi)
    assert plan.n_alive == N and plan.host.alive is None

    # no stored edge touches a dead point (rows nor columns)
    r2, c2, _ = p2.coo
    dead_cl = p2.host.inv[kill]
    assert not np.isin(r2, dead_cl).any()
    assert not np.isin(c2, dead_cl).any()

    # matvec: dead rows produce zero, dead columns contribute nothing
    xv = rng.standard_normal(N).astype(np.float32)
    y = np.asarray(p2.matvec(jnp.asarray(xv)))
    assert np.abs(y[kill]).max() == 0.0
    np.testing.assert_allclose(y, _masked_dense_matvec(p2, xv), atol=1e-4)


def test_delete_validation(plan):
    with pytest.raises(ValueError, match="out of range"):
        plan.delete([N + 3])
    p2 = plan.delete([7])
    with pytest.raises(ValueError, match="already-dead"):
        p2.delete([7])
    with pytest.raises(ValueError, match="live points"):
        plan.delete(np.arange(N - K))  # would leave <= k survivors


# ---------------------------------------------------------------------------
# insert: leaf placement, slot reuse, capacity growth
# ---------------------------------------------------------------------------


def test_insert_reuses_tombstoned_slots(plan):
    rng = np.random.default_rng(2)
    kill = rng.choice(N, 30, replace=False)
    p2 = plan.delete(kill)
    xin = _fresh_points(30, seed=5)
    p3, ids = p2.insert(xin)
    assert p3.capacity == N and p3.n_alive == N
    assert sorted(ids.tolist()) == sorted(kill.tolist())
    np.testing.assert_array_equal(p3.host.x[ids], xin)
    st = p3.refresh_stats
    assert st.last_action == "append" and st.appends == 1
    assert st.inserted_total == 30

    # inserted rows have exactly k live neighbors, and their stored COO
    # agrees with the bsr matvec
    r2, c2, _ = p3.coo
    for i in ids[:5]:
        assert (r2 == p3.host.inv[i]).sum() == K
    xv = rng.standard_normal(N).astype(np.float32)
    y_bsr = np.asarray(p3.matvec(jnp.asarray(xv), backend="bsr"))
    y_csr = np.asarray(p3.matvec(jnp.asarray(xv), backend="csr"))
    np.testing.assert_allclose(y_bsr, y_csr, atol=1e-4)


def test_insert_places_near_leaf(plan):
    """A point re-inserted at a deleted point's coordinates claims a slot
    near the hole it left (locality heuristic of the placement)."""
    kill = np.array([123])
    p2 = plan.delete(kill)
    x_back = plan.host.x[kill]          # same coordinates, new identity
    p3, ids = p2.insert(x_back)
    assert ids.tolist() == [123]        # the one free slot is its own hole


def test_insert_grows_capacity(plan):
    xin = _fresh_points(20, seed=6)
    p2 = api.update_plan(plan, insert=xin, policy="append")
    st = p2.refresh_stats
    assert p2.capacity > N and p2.capacity % plan.config.bs == 0
    assert p2.n_alive == N + 20
    assert st.grows == 1
    assert p2.bsr.n_rb == p2.capacity // plan.config.bs
    # grown capacity beyond the inserted points is tombstoned tail
    assert int(p2.alive.sum()) == N + 20
    # matvec still self-consistent
    xv = np.random.default_rng(3).standard_normal(p2.n).astype(np.float32)
    y_bsr = np.asarray(p2.matvec(jnp.asarray(xv), backend="bsr"))
    np.testing.assert_allclose(y_bsr, _masked_dense_matvec(p2, xv),
                               atol=1e-4)


def test_build_with_capacity_preallocates(points):
    p = api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                       ell_slack=8, capacity=N + 64)
    assert p.capacity == N + 64 and p.n_alive == N
    assert p.dead_frac > 0
    xin = _fresh_points(40, seed=7)
    p2, ids = p.insert(xin)
    assert p2.capacity == N + 64          # no reallocation needed
    assert p2.refresh_stats.grows == 0
    assert (ids >= N).all()               # landed in the pre-allocated tail


# ---------------------------------------------------------------------------
# compact tier
# ---------------------------------------------------------------------------


def test_compact_bit_exact_vs_fresh_build(plan):
    rng = np.random.default_rng(4)
    kill = rng.choice(N, 40, replace=False)
    p2 = plan.delete(kill)
    p3, _ = p2.insert(_fresh_points(10, seed=8))
    p4 = p3.compact()
    st = p4.refresh_stats
    assert st.last_action == "compact" and st.compactions == 1
    assert p4.capacity == p4.n_alive == N - 30

    fresh = api.build_plan(p3.host.x[p3.alive], config=p3.config)
    xv = jnp.asarray(rng.standard_normal(p4.n), jnp.float32)
    y_c = np.asarray(p4.matvec(xv))
    y_f = np.asarray(fresh.matvec(xv))
    assert np.array_equal(y_c, y_f), "compact must equal a fresh build"

    # compact_map: old physical slot -> new index, -1 for slots still
    # dead at compact time (10 of the 40 holes were re-claimed by inserts)
    cmap = p4.host.compact_map
    assert cmap is not None
    np.testing.assert_array_equal(cmap == -1, ~p3.alive)
    surv = np.nonzero(cmap >= 0)[0]
    np.testing.assert_array_equal(p4.host.x[cmap[surv]], p3.host.x[surv])


def test_dead_frac_triggers_compact(plan):
    cfg_kill = int(N * 0.30)
    rng = np.random.default_rng(5)
    kill = rng.choice(N, cfg_kill, replace=False)
    p2 = api.update_plan(plan, delete=kill)   # 30% dead > max_dead_frac
    assert p2.refresh_stats.last_action == "compact"
    assert p2.capacity == p2.n_alive == N - cfg_kill


def test_ell_overflow_restripes_storage(points):
    # zero slack: free slots inside the widest (already ELL-full) blocks,
    # then insert far-away points that claim those holes — their
    # scattered neighbor tiles cannot fit in place, so the storage is
    # restriped (ordering kept, ELL width re-derived)
    p = api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                       ell_slack=0)
    widths = np.asarray(p.bsr.nbr_mask).sum(1)
    wide = np.argsort(widths)[::-1][:8]        # 8 widest row-blocks
    victims = p.host.pi[np.concatenate(
        [np.arange(rb * 16, rb * 16 + 2) for rb in wide])]
    p2 = p.delete(victims)
    far = np.tile(points.max(0) * 4.0, (len(victims), 1)) \
        + _fresh_points(len(victims), seed=9) * 0.01
    p3 = api.update_plan(p2, insert=far)
    st = p3.refresh_stats
    assert st.restripes == 1 and st.last_action == "append"
    assert p3.bsr.max_nbr > p2.bsr.max_nbr     # width re-derived
    np.testing.assert_array_equal(p3.host.pi, p2.host.pi)  # ordering kept
    # restriped storage still agrees with the COO
    xv = np.random.default_rng(10).standard_normal(p3.n).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(p3.matvec(jnp.asarray(xv), backend="bsr")),
        np.asarray(p3.matvec(jnp.asarray(xv), backend="csr")), atol=1e-4)
    # forced in-place policy refuses instead of restriping
    with pytest.raises(ValueError, match="ELL|ell_slack"):
        api.update_plan(p2, insert=far, policy="append")


def test_streaming_policy_validation(plan):
    with pytest.raises(ValueError, match="unknown streaming policy"):
        api.update_plan(plan, delete=[0], policy="nope")
    prof = api.build_plan(np.asarray(plan.host.x), k=K, ordering="scattered",
                          with_bsr=False)
    with pytest.raises(ValueError, match="not streamable"):
        api.update_plan(prof, delete=[0])
    frozen = api.build_plan(np.asarray(plan.host.x), k=K, bs=16,
                            values=np.ones(N * K, np.float32))
    with pytest.raises(ValueError, match="not streamable"):
        frozen.delete([0])


# ---------------------------------------------------------------------------
# measures: gamma ignores dead rows
# ---------------------------------------------------------------------------


def test_gamma_ignores_dead_rows(plan):
    rng = np.random.default_rng(6)
    kill = rng.choice(N, 50, replace=False)
    p2 = plan.delete(kill)
    g_stream = p2.gamma
    fresh = api.build_plan(plan.host.x[p2.alive], config=plan.config)
    assert g_stream == pytest.approx(fresh.gamma, rel=0.25), \
        "streamed gamma (holes compacted) must track a fresh build"


def test_compact_live_projection():
    alive = np.array([True, False, True, True, False, True])
    rows = np.array([0, 2, 3, 1, 5])
    cols = np.array([2, 3, 5, 0, 4])
    r, c, n = measures.compact_live(rows, cols, alive)
    assert n == 4
    # edges touching dead slots 1 and 4 dropped; survivors renumbered
    np.testing.assert_array_equal(r, [0, 1, 2])
    np.testing.assert_array_equal(c, [1, 2, 3])


# ---------------------------------------------------------------------------
# storage primitives
# ---------------------------------------------------------------------------


def test_append_rows_grows_empty_capacity():
    bsr = blocksparse.random_bsr(0, 96, 16, 3, sb=4)
    big = blocksparse.append_rows(bsr, 160, extra_nbr=2)
    assert big.n == 160 and big.n_rb == 10 and big.n_cb == 10
    assert big.max_nbr == bsr.max_nbr + 2
    d0 = bsr.to_dense()
    d1 = big.to_dense()
    np.testing.assert_array_equal(d1[:96, :96], d0)
    assert not d1[96:].any() and not d1[:, 96:].any()
    assert not np.asarray(big.nbr_mask)[6:].any()
    with pytest.raises(ValueError, match="shrink"):
        blocksparse.append_rows(bsr, 64)


def test_tombstone_rows_scrubs_rows_and_referencing_blocks():
    rng = np.random.default_rng(0)
    n, bs = 128, 16
    rows = rng.integers(0, n, 600)
    cols = rng.integers(0, n, 600)
    vals = rng.standard_normal(600).astype(np.float32)
    bsr = blocksparse.build_bsr(rows, cols, vals, n, bs=bs, sb=4)
    dead = np.array([5, 17, 70])
    b2, r2, c2, v2, touched = blocksparse.tombstone_rows(
        bsr, rows, cols, vals, dead)
    keep = ~(np.isin(rows, dead) | np.isin(cols, dead))
    ref = blocksparse.build_bsr(rows[keep], cols[keep], vals[keep], n,
                                bs=bs, sb=4, max_nbr=bsr.max_nbr)
    np.testing.assert_allclose(b2.to_dense(), ref.to_dense(), atol=0)
    assert len(r2) == keep.sum()
    assert touched.size > 0
    # untouched blocks' tiles are shared, not copied
    d = b2.to_dense()
    assert not d[dead].any() and not d[:, dead].any()


def test_insertion_positions_and_claiming():
    codes = np.array([1, 3, 3, 7, 9, 20], np.uint64)
    tgt = hierarchy.insertion_positions(codes, np.array([0, 4, 50],
                                                       np.uint64))
    assert tgt.tolist() == [0, 3, 6]
    # non-monotone input (stale hole codes) still yields sane positions
    tgt2 = hierarchy.insertion_positions(
        np.array([1, 9, 3, 20], np.uint64), np.array([4], np.uint64))
    assert 1 <= tgt2[0] <= 3

    free = np.array([2, 10, 11, 40])
    got = claim_free_slots(free, np.array([10, 10, 3, 39]))
    assert sorted(got.tolist()) == [2, 10, 11, 40]
    assert got[0] == 10 and got[2] == 2 and got[3] == 40
    with pytest.raises(ValueError, match="free slots"):
        claim_free_slots(np.array([1]), np.array([0, 1]))


# ---------------------------------------------------------------------------
# churn loop: the benchmark scenario in miniature
# ---------------------------------------------------------------------------


def test_sustained_churn_stays_consistent(plan):
    rng = np.random.default_rng(7)
    p = plan
    for step in range(6):
        live = np.nonzero(p.alive)[0]
        kill = rng.choice(live, 12, replace=False)
        xin = _fresh_points(12, seed=100 + step)
        p = api.update_plan(p, insert=xin, delete=kill)
        # storage and COO stay in lockstep every step
        xv = rng.standard_normal(p.n).astype(np.float32)
        y_bsr = np.asarray(p.matvec(jnp.asarray(xv), backend="bsr"))
        y_csr = np.asarray(p.matvec(jnp.asarray(xv), backend="csr"))
        np.testing.assert_allclose(y_bsr, y_csr, atol=1e-4)
    st = p.refresh_stats
    assert st.inserted_total == 72 and st.deleted_total == 72
    assert st.appends + st.compactions >= 6


# ---------------------------------------------------------------------------
# sharded composition
# ---------------------------------------------------------------------------


def test_sharded_update_matches_single_device(plan):
    rng = np.random.default_rng(8)
    sp = api.shard(plan)
    p = plan
    for step in range(3):
        # regional churn (one cluster-order run retires and is replaced
        # in place) so the update stays on the narrow patch path
        pos = 40 * (step + 1)
        kill = np.asarray(p.host.pi[pos:pos + 6], np.int64)
        xin = p.host.x[kill] + 0.01 * rng.standard_normal(
            (6, D)).astype(np.float32)
        p = api.update_plan(p, insert=xin, delete=kill)
        sp = sp.update(insert=xin, delete=kill)
        assert sp.plan.n_alive == p.n_alive
        xv = jnp.asarray(rng.standard_normal(p.n), jnp.float32)
        y = np.asarray(p.matvec(xv, backend="bsr"))
        y_sh = np.asarray(sp.matvec(xv))
        np.testing.assert_allclose(y, y_sh, atol=1e-3)
    # the in-place tiers must actually patch shards, not quietly fall
    # back to a full re-shard every step
    assert sp.shard_patches >= 1


def test_sharded_update_reshards_on_compact(plan):
    sp = api.shard(plan)
    sp2 = sp.update(policy="compact")
    assert sp2.reshards == sp.reshards + 1
    assert sp2.plan.refresh_stats.last_action == "compact"
    xv = jnp.asarray(np.random.default_rng(9).standard_normal(sp2.plan.n),
                     jnp.float32)
    y = np.asarray(sp2.plan.matvec(xv, backend="bsr"))
    np.testing.assert_allclose(np.asarray(sp2.matvec(xv)), y, atol=1e-3)


# ---------------------------------------------------------------------------
# checkpoint: capacity/tombstone state round-trips bit-exactly
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_streaming_state(plan, tmp_path):
    rng = np.random.default_rng(10)
    kill = rng.choice(N, 20, replace=False)
    p2 = plan.delete(kill)
    p3, ids = p2.insert(_fresh_points(8, seed=11))

    ck = Checkpointer(tmp_path)
    ck.save_plan(1, p3, blocking=True)
    r, step = ck.restore_plan()
    assert step == 1
    assert r.capacity == p3.capacity and r.n_alive == p3.n_alive
    np.testing.assert_array_equal(r.alive, p3.alive)
    np.testing.assert_array_equal(r.host.codes, p3.host.codes)
    np.testing.assert_array_equal(r.host.x, p3.host.x)

    xv = jnp.asarray(rng.standard_normal(p3.n), jnp.float32)
    y0 = np.asarray(p3.matvec(xv))
    y1 = np.asarray(r.matvec(xv))
    assert np.array_equal(y0, y1), "restored streamed matvec bit-exact"

    # the restored plan keeps streaming
    live = np.nonzero(r.alive)[0]
    r2 = r.delete(live[:5])
    assert r2.n_alive == p3.n_alive - 5

# ---------------------------------------------------------------------------
# deferred layout + the double buffer (async maintenance)
# ---------------------------------------------------------------------------


def test_defer_layout_records_pending_and_stays_inplace(plan):
    rng = np.random.default_rng(12)
    kill = rng.choice(N, int(0.30 * N), replace=False)  # past max_dead_frac
    p2 = api.update_plan(plan, delete=kill, defer_layout=True)
    assert p2.host.pending_layout == "compact"
    assert p2.refresh_stats.compactions == 0
    assert p2.refresh_stats.last_action == "tombstone"
    assert p2.n == plan.n                       # layout untouched
    xv = rng.standard_normal(p2.n).astype(np.float32)
    dense = _masked_dense_matvec(p2, xv)
    np.testing.assert_allclose(np.asarray(p2.matvec(jnp.asarray(xv))),
                               dense, atol=1e-3)
    # a synchronous follow-up step clears the marker by escalating
    p3 = api.update_plan(p2, delete=np.nonzero(p2.alive)[0][:1])
    assert p3.host.pending_layout is None
    assert p3.refresh_stats.compactions == 1


def test_streamed_then_swapped_equals_fresh_build(plan):
    rng = np.random.default_rng(13)
    kill = rng.choice(N, int(0.30 * N), replace=False)
    p2 = api.update_plan(plan, delete=kill, defer_layout=True)
    assert p2.host.pending_layout == "compact"
    swapped = api.apply_pending_layout(p2)
    assert swapped.host.pending_layout is None
    assert swapped.refresh_stats.last_action == "compact"
    fresh = api.build_plan(p2.host.x[p2.alive], config=plan.config)
    np.testing.assert_array_equal(np.asarray(swapped.bsr.col_idx),
                                  np.asarray(fresh.bsr.col_idx))
    np.testing.assert_array_equal(np.asarray(swapped.bsr.vals),
                                  np.asarray(fresh.bsr.vals))
    xv = jnp.asarray(rng.standard_normal(swapped.n), jnp.float32)
    assert np.array_equal(np.asarray(swapped.matvec(xv)),
                          np.asarray(fresh.matvec(xv)))


def test_doublebuffer_midbuild_matvec_is_old_generation(points, monkeypatch):
    plan = api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8, capacity=N + 64, gamma_tol=1e-4)
    _ = plan.gamma                       # arm the drift guard
    gate = threading.Event()
    real = api.apply_pending_layout

    def gated(p):
        gate.wait(30)
        return real(p)

    monkeypatch.setattr(api, "apply_pending_layout", gated)
    dbp = DoubleBufferedPlan(plan)
    rng = np.random.default_rng(14)
    step = 0
    while not dbp.building:
        assert step < 20, "expected the gamma guard to defer a rebucket"
        kill = rng.choice(np.nonzero(dbp.plan.alive)[0], 8, replace=False)
        dbp.update(insert=_fresh_points(8, seed=20 + step), delete=kill)
        step += 1
    snap = dbp.plan
    xv = jnp.asarray(rng.standard_normal(snap.n), jnp.float32)
    y0 = np.asarray(snap.matvec(xv))
    # updates arriving mid-build queue; the serving buffer is frozen, so
    # a mid-build matvec returns the old generation's result bit-exactly
    assert dbp.update(insert=_fresh_points(4, seed=99)) == "queued"
    assert dbp.plan is snap
    assert np.array_equal(np.asarray(dbp.matvec(xv)), y0)
    gen0 = dbp.generation
    gate.set()
    dbp.wait()
    assert dbp.generation == gen0 + 1
    assert dbp.queued == 0               # the queued insert replayed
    # the swapped-in successor is bit-identical to running the same
    # repair synchronously on the snapshot
    snapshot, successor, kind = dbp.last_swap
    assert kind == "rebucket"
    redo = real(snapshot)
    np.testing.assert_array_equal(np.asarray(successor.bsr.vals),
                                  np.asarray(redo.bsr.vals))
    dbp.flush()


def test_doublebuffer_compact_swap_remaps_queued_deletes(points, monkeypatch):
    plan = api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8)
    gate = threading.Event()
    real = api.apply_pending_layout

    def gated(p):
        gate.wait(30)
        return real(p)

    monkeypatch.setattr(api, "apply_pending_layout", gated)
    dbp = DoubleBufferedPlan(plan)
    rng = np.random.default_rng(15)
    kill = rng.choice(N, int(0.30 * N), replace=False)
    assert dbp.update(delete=kill) == "applied"
    assert dbp.building                  # compact launched in background
    live = np.nonzero(dbp.plan.alive)[0]
    assert dbp.update(delete=live[:10]) == "queued"
    gate.set()
    final = dbp.flush()
    # the compact renumbered the physical slots; the queued delete was
    # remapped through compact_map and applied cleanly after the swap
    assert final.n_alive == N - kill.size - 10
    swaps = [e for e in dbp.events if e[0] == "swap"]
    assert swaps and swaps[0][1] == "compact" and swaps[0][2] is not None


def test_sharded_absorb_swap(plan):
    sp = api.shard(plan)
    p2 = api.update_plan(plan, delete=np.arange(160), defer_layout=True)
    assert p2.host.pending_layout == "compact"
    sp = sp.absorb(p2)                   # in-place tier: shard-local patch
    assert sp.plan is p2
    swapped = api.apply_pending_layout(p2)
    sp2 = sp.absorb(swapped)             # layout swap: re-shard, same mesh
    assert sp2.reshards == sp.reshards + 1
    rng = np.random.default_rng(16)
    xv = jnp.asarray(rng.standard_normal(swapped.n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(sp2.matvec(xv)),
        np.asarray(swapped.matvec(xv, backend="bsr")), atol=1e-3)
