"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_pipeline_matches_sequential():
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pp import pipeline_apply

mesh = jax.make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
S, B, D = 4, 8, 16
w = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D), jnp.float32)
b = jnp.asarray(rng.standard_normal((S, D)) * 0.1, jnp.float32)
x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

def stage_fn(p, xm):
    return jnp.tanh(xm @ p["w"] + p["b"])

y_pp = pipeline_apply({{"w": w, "b": b}}, x, stage_fn, mesh,
                      microbatches=4)
y_ref = x
for s in range(S):
    y_ref = jnp.tanh(y_ref @ w[s] + b[s])
err = float(jnp.abs(y_pp - y_ref).max())
assert err < 1e-5, err
print("pipeline OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    assert "pipeline OK" in r.stdout
