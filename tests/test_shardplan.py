"""Sharded plans (ISSUE 3): halo analysis, shard/unshard round-trips,
sharded matvec equivalence, minimal halos on banded patterns, incremental
shard refresh, and shard-aware checkpointing.

Host-side analysis tests run on any device count; matvec tests exercise
whatever mesh the process has (1 device under plain pytest, 8 under the CI
``multidevice`` job's ``--xla_force_host_platform_device_count=8``); one
subprocess test pins the 8-device behavior even in a single-device run.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import shardplan
from repro.core.blocksparse import random_bsr
from repro.data.pipeline import feature_mixture

SRC = str(Path(__file__).resolve().parents[1] / "src")
N, D, K = 512, 32, 8


@pytest.fixture(scope="module")
def clustered():
    return feature_mixture(N, D, n_clusters=8, seed=0)


@pytest.fixture(scope="module")
def plan(clustered):
    return api.build_plan(clustered, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8)


# ---------------------------------------------------------------------------
# halo analysis (pure host — no devices involved)
# ---------------------------------------------------------------------------


def test_banded_halo_is_minimal():
    """A banded pattern must get the slice-halo mode, never all-gather,
    and its halo must be bounded by the band width."""
    nbr = 4
    bsr = random_bsr(0, 2048, 32, nbr, banded=True)
    for n_dev in (2, 4, 8):
        spec, _ = shardplan.analyze_shards(bsr, n_dev)
        assert spec.mode == "halo", f"banded fell back to {spec.mode}"
        assert spec.halo_lo + spec.halo_hi <= nbr
        assert spec.transfer_blocks < spec.allgather_blocks


def test_clustered_plan_beats_allgather(plan):
    """The whole point: under the cluster ordering, per-device transfer is
    strictly below replicating the charge vector."""
    for n_dev in (2, 4, 8):
        spec, hot = shardplan.analyze_shards(plan.bsr, n_dev)
        assert spec.transfer_blocks < spec.allgather_blocks, (
            f"{n_dev}-dev: {spec.mode} transfers {spec.transfer_blocks} "
            f">= all-gather {spec.allgather_blocks}")


def test_scattered_pattern_falls_back_to_allgather():
    bsr = random_bsr(3, 2048, 32, 8, banded=False)   # global support
    spec, _ = shardplan.analyze_shards(bsr, 8)
    assert spec.mode == "allgather"
    assert spec.transfer_blocks == spec.allgather_blocks


def test_analysis_covers_every_devices_support(plan):
    """Every column a device references must lie in its halo window or in
    the replicated hot set — nothing may be silently dropped."""
    col = np.asarray(plan.bsr.col_idx)
    mask = np.asarray(plan.bsr.nbr_mask)
    for n_dev in (2, 4, 8):
        spec, hot = shardplan.analyze_shards(plan.bsr, n_dev)
        for d in range(n_dev):
            r0 = d * spec.rb_per
            r1 = min(r0 + spec.rb_per, plan.bsr.n_rb)
            cols = np.unique(col[r0:r1][mask[r0:r1]])
            if cols.size == 0:
                continue
            base = spec.window_base(d)
            in_win = (cols >= base) & (cols < base + spec.win)
            assert np.isin(cols[~in_win], hot).all(), (
                f"{n_dev}-dev device {d}: columns outside window+hot")


# ---------------------------------------------------------------------------
# shard / unshard / matvec (current process mesh: 1..8 devices)
# ---------------------------------------------------------------------------


def test_shard_unshard_bit_identical(plan):
    sp = api.shard(plan)
    b2 = sp.unshard()
    b = plan.bsr
    np.testing.assert_array_equal(np.asarray(b2.col_idx),
                                  np.asarray(b.col_idx))
    np.testing.assert_array_equal(np.asarray(b2.nbr_mask),
                                  np.asarray(b.nbr_mask))
    np.testing.assert_array_equal(np.asarray(b2.vals), np.asarray(b.vals))
    assert (b2.bs, b2.sb, b2.n, b2.max_nbr) == (b.bs, b.sb, b.n, b.max_nbr)


@pytest.mark.parametrize("kind", ["uniform", "clustered"])
def test_sharded_matvec_matches_unsharded(kind, clustered):
    if kind == "uniform":
        x = np.random.default_rng(0).standard_normal((N, D)).astype(
            np.float32)
    else:
        x = clustered
    p = api.build_plan(x, k=K, bs=16, sb=4, backend="bsr")
    sp = api.shard(p)
    q = jnp.asarray(np.random.default_rng(1).standard_normal(N), jnp.float32)
    y_sh = np.asarray(sp.matvec(q))
    y_ref = np.asarray(p.matvec(q, backend="bsr"))
    np.testing.assert_allclose(y_sh, y_ref, atol=1e-4)


def test_sharded_rejects_matrix_charges(plan):
    sp = api.shard(plan)
    with pytest.raises(ValueError, match="1-D"):
        sp.apply(jnp.ones((N, 3)))


def test_shard_requires_bsr(clustered):
    profile = api.build_plan(clustered, k=K, with_bsr=False)
    with pytest.raises(ValueError, match="profile-only"):
        api.shard(profile)


def test_dist_backend_caches_shards(plan):
    q = jnp.asarray(np.random.default_rng(2).standard_normal(N), jnp.float32)
    y1 = plan.apply(q, backend="dist")
    sp = next(iter(plan.host.shard_cache.values()))
    y2 = plan.apply(q, backend="dist")
    assert next(iter(plan.host.shard_cache.values())) is sp, \
        "dist backend must reuse the memoized shards"
    y_ref = plan.apply(q, backend="bsr")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref), atol=1e-4)


def test_autotune_prefers_dist_on_multidevice(plan):
    """Device-count-aware tuning: on a >=2-device mesh the sharded path
    wins whenever its analyzed transfer beats replication (the analysis is
    host-side, so this holds regardless of this process's device count)."""
    from repro.core.autotune import tune_backend
    name, times = tune_backend(plan, device_count=8)
    if "dist" in times:
        assert name == "dist"
    name1, times1 = tune_backend(plan, device_count=1)
    if times1:
        assert name1 == min(times1, key=times1.get)


# ---------------------------------------------------------------------------
# incremental shard refresh (compose with the PR 2 lifecycle)
# ---------------------------------------------------------------------------


def _teleport(x, frac, seed=1):
    rng = np.random.default_rng(seed)
    x2 = x.copy()
    mv = rng.choice(len(x), size=max(int(len(x) * frac), 1), replace=False)
    x2[mv] = x[(mv + len(x) // 2) % len(x)]
    x2[mv] += 0.01 * rng.standard_normal((len(mv), x.shape[1])
                                         ).astype(np.float32)
    return x2


def test_shard_refresh_patch_matches_global(plan, clustered):
    x2 = _teleport(clustered, 0.03)
    sp = api.shard(plan)
    sp2 = sp.refresh(x2, policy="patch")
    assert sp2.plan.refresh_stats.last_action == "patch"
    # incremental: shards were patched in place, not re-analyzed
    assert sp2.shard_patches + sp2.reshards >= 1
    # equivalence with the globally refreshed plan
    global_ref = plan.refresh(x2, policy="patch")
    q = jnp.asarray(np.random.default_rng(3).standard_normal(N), jnp.float32)
    np.testing.assert_allclose(np.asarray(sp2.matvec(q)),
                               np.asarray(global_ref.matvec(q,
                                                            backend="bsr")),
                               atol=1e-4)
    if sp2.shard_patches:     # in-place patch: unshard == refreshed BSR
        b2, bg = sp2.unshard(), global_ref.bsr
        np.testing.assert_array_equal(np.asarray(b2.col_idx),
                                      np.asarray(bg.col_idx))
        np.testing.assert_array_equal(np.asarray(b2.vals),
                                      np.asarray(bg.vals))


def test_shard_refresh_patches_only_owning_shards(plan, clustered):
    # local jitter (not a cross-cluster teleport): migrated rows' new kNN
    # columns stay inside the halo window, so the *incremental* path runs
    rng = np.random.default_rng(7)
    x2 = (clustered + 0.08 * rng.standard_normal(clustered.shape)
          ).astype(np.float32)
    sp = api.shard(plan)
    sp2 = sp.refresh(x2, policy="patch")
    touched = sp2.plan.host.last_patch_rb
    if sp2.shard_patches == 0 or touched is None or len(touched) == 0:
        pytest.skip("teleport did not trigger an in-window patch")
    # spec (and compiled exchange) identical — no halo re-analysis
    assert sp2.spec is sp.spec
    untouched = np.setdiff1d(np.arange(plan.bsr.n_rb), touched)
    np.testing.assert_array_equal(np.asarray(sp2.lcol)[untouched],
                                  np.asarray(sp.lcol)[untouched])
    np.testing.assert_array_equal(np.asarray(sp2.vals)[untouched],
                                  np.asarray(sp.vals)[untouched])


def test_shard_refresh_rebucket_reshards(plan, clustered):
    x2 = _teleport(clustered, 0.35, seed=5)
    sp = api.shard(plan)
    sp2 = sp.refresh(x2, policy="rebucket")
    assert sp2.reshards == 1
    assert sp2.plan.refresh_stats.last_action == "rebucket"
    q = jnp.asarray(np.random.default_rng(4).standard_normal(N), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(sp2.matvec(q)),
        np.asarray(sp2.plan.matvec(q, backend="bsr")), atol=1e-4)


# ---------------------------------------------------------------------------
# shard-aware checkpoint round-trip
# ---------------------------------------------------------------------------


def test_ckpt_sharded_round_trip(plan, tmp_path):
    from repro.checkpoint.ckpt import Checkpointer
    ck = Checkpointer(tmp_path)
    sp = api.shard(plan)
    ck.save_plan(0, sp, blocking=True)
    restored, step = ck.restore_plan(0, mesh="auto")
    assert step == 0
    assert isinstance(restored, api.ShardedPlan)
    assert restored.spec.axis == sp.spec.axis
    q = jnp.asarray(np.random.default_rng(5).standard_normal(N), jnp.float32)
    np.testing.assert_array_equal(np.asarray(restored.matvec(q)),
                                  np.asarray(sp.matvec(q)))
    # without a mesh the plain (unsharded) plan comes back
    plain, _ = ck.restore_plan(0)
    assert isinstance(plain, api.InteractionPlan)
    np.testing.assert_array_equal(np.asarray(plain.bsr.vals),
                                  np.asarray(plan.bsr.vals))


# ---------------------------------------------------------------------------
# 8-device pin (subprocess, like tests/test_dist.py)
# ---------------------------------------------------------------------------


def test_eight_device_halo_exchange_subprocess():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {SRC!r})
        import jax
        assert jax.device_count() == 8
        import numpy as np, jax.numpy as jnp
        from repro import api
        from repro.data.pipeline import feature_mixture

        x = feature_mixture(1024, 32, n_clusters=16, seed=0)
        plan = api.build_plan(x, k=8, bs=16, sb=4, backend="bsr")
        sp = api.shard(plan)
        assert sp.spec.n_dev == 8
        assert sp.spec.transfer_blocks < sp.spec.allgather_blocks, \\
            "clustered pattern must beat all-gather on 8 devices"
        q = jnp.asarray(np.random.default_rng(1).standard_normal(1024),
                        jnp.float32)
        y = np.asarray(sp.matvec(q))
        y_ref = np.asarray(plan.matvec(q, backend="bsr"))
        assert np.abs(y - y_ref).max() < 1e-4
        # backend="auto" picks the sharded dist path on a multi-device mesh
        auto = api.build_plan(x, k=8, bs=16, sb=4, backend="auto")
        assert auto.resolve_backend(x=q) == "dist", auto.resolve_backend(x=q)
        print("8-device halo exchange OK:", sp)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
