"""Paper Table 1: gamma-score (sigma = k/2) of the SIFT/GIST interaction
matrices under each ordering. Offline stand-in datasets (DESIGN.md §4);
the claim reproduced is the ORDERING of the scores: dual_tree > lexical >
1D/rCM > scattered. Profile-only plans (no BSR) score each ordering."""
from __future__ import annotations

from benchmarks.common import dataset
from repro import api

from repro.configs.paper_spmv import TABLE1


def run(out):
    for exp in TABLE1:
        ds, n, k, sigma = (exp.dataset, exp.n_points, exp.k_neighbors,
                           exp.sigma)
        x = dataset(ds, n)
        for name in exp.orderings:
            plan = api.build_plan(x, k=k, ordering=name, symmetrize=True,
                                  sigma=sigma, with_bsr=False)
            out(f"table1_{ds}_{name},{plan.gamma:.3f},k={k};sigma={sigma}")
