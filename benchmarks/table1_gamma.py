"""Paper Table 1: gamma-score (sigma = k/2) of the SIFT/GIST interaction
matrices under each ordering. Offline stand-in datasets (DESIGN.md §4);
the claim reproduced is the ORDERING of the scores: dual_tree > lexical >
1D/rCM > scattered."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import knn_problem, reorder
from repro.core import measures


from repro.configs.paper_spmv import TABLE1


def run(out):
    for exp in TABLE1:
        ds, n, k, sigma = (exp.dataset, exp.n_points, exp.k_neighbors,
                           exp.sigma)
        x, rows, cols = knn_problem(ds, n, k)
        for name in exp.orderings:
            _, r2, c2 = reorder(name, x, rows, cols)
            g = float(measures.gamma_score(jnp.asarray(r2), jnp.asarray(c2),
                                           sigma, n))
            out(f"table1_{ds}_{name},{g:.3f},k={k};sigma={sigma}")
