"""Shared benchmark helpers: timing + the SIFT/GIST-like working sets."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn
from repro.data.pipeline import gist_like, sift_like


def timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time (s) of fn(*args) with device sync."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def dataset(name: str, n: int, seed: int = 0) -> np.ndarray:
    if name == "sift":
        return sift_like(n, seed)
    if name == "gist":
        return gist_like(n, seed)
    raise ValueError(name)


def knn_problem(name: str, n: int, k: int, seed: int = 0):
    """Returns (x, rows, cols) for a symmetrized kNN interaction pattern."""
    x = dataset(name, n, seed)
    rows, cols, _ = knn.knn_coo(jnp.asarray(x), jnp.asarray(x), k,
                                block=1024, exclude_self=True)
    rows, cols = np.asarray(rows), np.asarray(cols)
    # symmetrize (paper Fig. 2 uses symmetrized interactions)
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    key = r2.astype(np.int64) * n + c2
    _, first = np.unique(key, return_index=True)
    return x, r2[first], c2[first]
