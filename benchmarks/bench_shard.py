"""Sharded-plan matvec: halo exchange vs single-device block SpMV.

The ROADMAP's serving posture wants the ``dist`` backend to *win* on
multi-device meshes, not merely match. This benchmark times the sharded
halo-exchange matvec (``api.shard(plan, mesh)``) against the single-device
``bsr`` backend on the same plan, on whatever mesh the process has:

  banded_gate   n=16384, 16 dense tiles/row-block (paper §4.1 banded
                best case) — the ACCEPTANCE scenario: on a >=8-device
                mesh the sharded matvec must be >=1.5x faster than
                single-device ``bsr`` (asserted, like bench_refresh)
  banded_wide   n=32768, 8 tiles/row-block — scaling headroom (reported)
  clustered     a real ``build_plan`` over a feature mixture — reports
                the halo transfer fraction the cluster ordering earns
                (the quantity all-gather would pin at 1.0)

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src:. python benchmarks/run.py --only bench_shard
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro import api
from repro.core.blocksparse import random_bsr
from repro.data.pipeline import feature_mixture

GATE_DEVICES = 8        # gate only on a real multi-device mesh
GATE_MIN_N = 16384      # and only at serving-relevant sizes
GATE_SPEEDUP = 1.5


def _compare(plan, name: str, emit):
    sp = api.shard(plan)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(plan.n),
                    jnp.float32)
    t_bsr = timeit(lambda: plan.apply(x, backend="bsr"), warmup=2, iters=10)
    t_sh = timeit(lambda: sp.apply(x), warmup=2, iters=10)
    y = np.asarray(sp.apply(x))
    y_ref = np.asarray(plan.apply(x, backend="bsr"))
    err = float(np.abs(y - y_ref).max())
    assert err < 1e-3, f"sharded matvec diverged: {err:.2e}"
    speedup = t_bsr / t_sh
    emit(f"bench_shard/{name}_bsr,{t_bsr*1e6:.0f},devices={sp.spec.n_dev}")
    emit(f"bench_shard/{name}_sharded,{t_sh*1e6:.0f},"
         f"speedup={speedup:.2f}x;mode={sp.spec.mode};"
         f"transfer={sp.transfer_fraction:.3f}")
    return speedup, sp


def run(emit) -> None:
    ndev = jax.device_count()

    bsr = random_bsr(0, 16384, 32, 16, banded=True)
    assert bsr.n >= GATE_MIN_N, "gate scenario must stay serving-sized"
    speedup, _ = _compare(api.InteractionPlan.from_bsr(bsr), "banded_gate",
                          emit)
    if ndev >= GATE_DEVICES:
        # ISSUE 3 acceptance: sharded matvec >=1.5x single-device bsr on
        # >=8 devices at n>=16k
        assert speedup >= GATE_SPEEDUP, (
            f"sharded matvec {speedup:.2f}x < {GATE_SPEEDUP}x over "
            f"single-device bsr on {ndev} devices (n=16384)")

    bsr = random_bsr(1, 32768, 32, 8, banded=True)
    _compare(api.InteractionPlan.from_bsr(bsr), "banded_wide", emit)

    x = feature_mixture(8192, 32, n_clusters=32, seed=0)
    plan = api.build_plan(x, k=16, bs=32, sb=8, backend="bsr")
    _, sp = _compare(plan, "clustered", emit)
    assert sp.transfer_fraction <= 1.0
    if ndev >= 2:
        # the cluster ordering must keep the halo below replication
        assert sp.spec.transfer_blocks < sp.spec.allgather_blocks, (
            f"clustered plan fell back to {sp.spec.mode}: transfer "
            f"{sp.spec.transfer_blocks} blocks >= all-gather "
            f"{sp.spec.allgather_blocks}")


if __name__ == "__main__":
    run(print)
