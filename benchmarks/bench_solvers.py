"""Batched preconditioned CG on the plan operator: the solver gates.

ISSUE 10 acceptance: a batch of 64 KRR systems (n=1024 each, RBF-dressed
symmetrized kNN kernels on clustered clouds) solved to rtol 1e-5 by
block-Jacobi-preconditioned CG must show

  iterations  >= 2x fewer CG iterations than unpreconditioned CG (the
              block-Jacobi factor is sliced from the plan's own diagonal
              BSR tiles — the preconditioner is free structure);
  wall-clock  >= 5x faster than a per-plan python solve loop — the
              pre-solvers reality: an eager python-level CG per plan
              driving ``plan.matvec`` (same math, same preconditioner,
              same tolerance; every iteration pays op dispatch and a
              host sync on the convergence check);
  one trace   the batched solver kernel compiles exactly ONCE for the
              whole batch (counted via an instrumented backend);
  reference   every member's solution matches a dense ``scipy`` solve of
              the very same truncated kernel to rtol 1e-4.

The shift is fixed just above the measured spectral floor of the
truncated kernel (|lambda_min| ~ 3.5 on this data — truncation destroys
positive definiteness, see ``docs/solvers.md``), which is the
ill-conditioned regime where preconditioning pays: Gershgorin's
``self_weight="auto"`` shift is safe but over-regularizes the contrast
away.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_solvers
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro import api
from repro.core import registry
from repro.data.pipeline import feature_mixture
from repro.solvers import RBFValues
from repro.solvers.krr import solve

B, N, D, K = 64, 1024, 32, 8
BS, SB = 64, 4
SHIFT = 3.55            # just above |lambda_min| of the truncated kernel
TOL, MAXITER = 1e-5, 512
GATE_ITERS = 2.0
GATE_SPEEDUP = 5.0


def run(emit) -> None:
    rng = np.random.default_rng(0)
    xs = [feature_mixture(N, D, n_clusters=32, seed=s, spread=0.05)
          for s in range(B)]
    batch = api.build_plan_batch(xs, k=K, bs=BS, sb=SB, backend="bsr",
                                 symmetrize=True, values=RBFValues())
    y = jnp.asarray(rng.standard_normal((B, batch.capacity)), jnp.float32)

    # -- one-compilation gate: the batched solver traces exactly once ------
    calls = []

    @api.register_backend("bench_solvers_counter")
    def _counting(p, x, **kw):
        calls.append(1)
        return api.get_backend("bsr")(p, x)

    try:
        jax.block_until_ready(solve(
            batch, y, shift=SHIFT, backend="bench_solvers_counter",
            precond="block_jacobi", tol=TOL, maxiter=MAXITER).x)
        jax.block_until_ready(solve(
            batch, y, shift=SHIFT, backend="bench_solvers_counter",
            precond="block_jacobi", tol=TOL, maxiter=MAXITER).x)
        n_traces = len(calls)
    finally:
        registry._BACKENDS.pop("bench_solvers_counter", None)
    assert n_traces == 1, (
        f"batched solve traced {n_traces}x for a batch of {B}; the "
        "solver contract is ONE compilation for the whole batch")

    # -- iteration gate: block-Jacobi vs unpreconditioned ------------------
    r_id = solve(batch, y, shift=SHIFT, precond="identity",
                 tol=TOL, maxiter=MAXITER)
    r_bj = solve(batch, y, shift=SHIFT, precond="block_jacobi",
                 tol=TOL, maxiter=MAXITER)
    assert bool(np.asarray(r_id.converged).all()), \
        "unpreconditioned CG failed to reach rtol 1e-5"
    assert bool(np.asarray(r_bj.converged).all()), \
        "block-Jacobi CG failed to reach rtol 1e-5"
    it_id = float(np.asarray(r_id.iters).mean())
    it_bj = float(np.asarray(r_bj.iters).mean())
    ratio = it_id / it_bj
    emit(f"bench_solvers/iters_identity_B{B}_n{N},{it_id:.1f},"
         f"max={int(np.asarray(r_id.iters).max())}")
    emit(f"bench_solvers/iters_block_jacobi_B{B}_n{N},{it_bj:.1f},"
         f"max={int(np.asarray(r_bj.iters).max())};ratio={ratio:.2f}x")
    assert ratio >= GATE_ITERS, (
        f"block-Jacobi saved only {ratio:.2f}x iterations "
        f"({it_bj:.1f} vs {it_id:.1f}) < {GATE_ITERS}x gate")

    # -- wall-clock gate: one batched kernel vs a per-plan python loop -----
    t_batched = timeit(
        lambda: solve(batch, y, shift=SHIFT, precond="block_jacobi",
                      tol=TOL, maxiter=MAXITER).x,
        warmup=2, iters=5)

    members = batch.members()           # single-plan views, built once

    from repro.solvers.precond import block_jacobi

    def eager_cg(m, b, M):
        # the pre-solvers reality: python-level PCG over plan.matvec —
        # identical math to solvers.cg, but every op is its own dispatch
        # and the convergence check syncs to host each iteration
        x = jnp.zeros_like(b)
        r = b
        z = M(r, axis=-1)
        p = z
        rz = jnp.vdot(r, z)
        target = float(TOL * jnp.linalg.norm(b))
        it = 0
        while it < MAXITER and float(jnp.linalg.norm(r)) > target:
            Ap = m.matvec(p) + SHIFT * p
            alpha = rz / jnp.vdot(p, Ap)
            x = x + alpha * p
            r = r - alpha * Ap
            z = M(r, axis=-1)
            rz_new = jnp.vdot(r, z)
            p = z + (rz_new / rz) * p
            rz = rz_new
            it += 1
        return x

    def loop():
        return [eager_cg(m, y[i], block_jacobi(m.spec, m.data, SHIFT))
                for i, m in enumerate(members)]

    t_loop = timeit(lambda: jax.block_until_ready(loop()),
                    warmup=1, iters=3)
    speedup = t_loop / t_batched
    emit(f"bench_solvers/batched_B{B}_n{N},{t_batched*1e6:.0f},"
         f"traces={n_traces};precond=block_jacobi")
    emit(f"bench_solvers/loop_B{B}_n{N},{t_loop*1e6:.0f},"
         f"speedup={speedup:.2f}x")
    assert speedup >= GATE_SPEEDUP, (
        f"batched solve {speedup:.2f}x < {GATE_SPEEDUP}x over the "
        f"single-plan loop (batched {t_batched*1e3:.2f}ms vs loop "
        f"{t_loop*1e3:.2f}ms)")

    # -- reference gate: every member against dense scipy ------------------
    from scipy.linalg import solve as dense_solve
    x_bj = np.asarray(r_bj.x)
    worst = 0.0
    for i in range(B):
        m = members[i]
        dense = np.asarray(m.bsr.to_dense()) + SHIFT * np.eye(m.n)
        pi, inv = np.asarray(m.pi), np.asarray(m.inv)
        ref = dense_solve(dense, np.asarray(y[i])[pi], assume_a="sym")[inv]
        err = float(np.abs(x_bj[i] - ref).max() / np.abs(ref).max())
        worst = max(worst, err)
    emit(f"bench_solvers/dense_ref_B{B}_n{N},{worst*1e6:.2f},"
         f"metric=max_rel_err_ppm")
    assert worst < 1e-4, (
        f"batched solve disagrees with the dense reference: "
        f"max rel err {worst:.2e} >= 1e-4")


if __name__ == "__main__":
    run(print)
