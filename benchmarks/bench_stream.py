"""Streaming churn: insert/delete tiers vs rebuilding the plan per step.

The ROADMAP's streaming item: growing/shrinking point sets must be served
by in-place append/tombstone tiers with an amortized compaction, not a
``build_plan`` per change. This suite streams sustained churn (<=5% of the
points inserted+deleted per step) through ``api.update_plan`` with two
churn shapes, mirroring bench_refresh's coherent/uniform split:

  coherent    one region's points retire and fresh arrivals replace them
              (a re-ingested shard / re-crawled region: deletions and
              insertions share leaves, so the streamed step patches a
              bounded set of row-blocks) — the ACCEPTANCE scenario:
              mean per-step wall time (amortized over any compactions /
              restripes the policy triggers) must be >=3x faster than a
              from-scratch ``build_plan`` on the survivors, with the
              streamed plan's γ (dead rows ignored) within 5% of a
              fresh build's
  uniform     churn scattered over the whole cloud — the in-place tiers'
              worst case (every row-block holds some edge of some
              deleted point). Served through
              ``core.doublebuf.DoubleBufferedPlan``: the in-place tiers
              run on-device on the critical path while γ-rebuckets and
              compactions build on a background thread and swap in
              atomically (ISSUE 8 acceptance: mean per-step wall time
              within ``GATE_UNIFORM``x of the coherent scenario's)

Also asserted in-suite: after an explicit compact, matvec is bit-exact
against a fresh build over the surviving points; every background swap
the uniform scenario adopted is bit-identical to re-running the same
layout repair synchronously on its snapshot; and on a >=2-device mesh
the same streamed sequence applied through ``ShardedPlan.update``
matches the single-device result.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_stream
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro import api
from repro.core.doublebuf import DoubleBufferedPlan

N, K, D = 16384, 16, 32
N_CLUSTERS = 16
CHURN = 0.025          # per side, per step  (insert + delete = 5%)
STEPS = 12
WARM = 6
GATE_SPEEDUP = 3.0
GATE_GAMMA = 0.05
GATE_UNIFORM = 1.5     # uniform churn (double-buffered) vs coherent


class _Stream:
    """A mixture feed with per-point cluster labels, so churn can be
    regional (coherent) or global (uniform)."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)
        basis = self.rng.standard_normal((8, D)) / np.sqrt(8)
        self.centers = (self.rng.standard_normal((N_CLUSTERS, 8)) @ basis
                        * 3.0).astype(np.float32)

    def initial(self):
        labels = self.rng.integers(0, N_CLUSTERS, N)
        x = (self.centers[labels] + 0.5 * self.rng.standard_normal((N, D))
             ).astype(np.float32)
        return x, labels

    def arrivals(self, c: int, m: int) -> np.ndarray:
        return (self.centers[c]
                + 0.5 * self.rng.standard_normal((m, D))).astype(np.float32)

    def batches(self, plan, labels, step: int, shape: str):
        m = int(N * CHURN)
        live = np.nonzero(plan.alive)[0]
        if shape == "coherent":
            c = step % N_CLUSTERS
            mine = live[labels[live] == c]
            take = min(m, len(mine))
            kill = self.rng.choice(mine, take, replace=False)
            if take < m:
                rest = np.setdiff1d(live, kill, assume_unique=False)
                kill = np.concatenate(
                    [kill, self.rng.choice(rest, m - take, replace=False)])
            xin = self.arrivals(c, m)
            lab = c
        else:
            kill = self.rng.choice(live, m, replace=False)
            c = int(self.rng.integers(0, N_CLUSTERS))
            xin = self.arrivals(c, m)
            lab = c
        return kill, xin, lab


def _apply(plan, labels, kill, xin, lab):
    plan = api.update_plan(plan, insert=xin, delete=kill)
    if len(labels) != plan.n:         # capacity grew or plan compacted
        cmap = plan.host.compact_map
        new_labels = np.full(plan.n, -1, np.int64)
        if cmap is not None:
            surv = np.nonzero(cmap >= 0)[0]
            new_labels[cmap[surv]] = labels[surv]
        else:
            new_labels[:len(labels)] = labels
        labels = new_labels
    ids = plan.host.last_inserted_idx
    if ids is not None:
        labels[ids] = lab
    return plan, labels


def _stream_scenario(shape: str, steps: int, sharded_too: bool):
    feed = _Stream(seed=0)
    x0, labels0 = feed.initial()
    # capacity slack interleaves free slots through the leaves (inserts
    # land in place); gamma_tol=0.03: the γ-drift guard rebuckets (stable
    # code re-sort + build_bsr, no kNN) well inside the 5% gate margin
    plan = api.build_plan(x0, k=K, bs=32, sb=8, backend="bsr",
                          ell_slack=4, gamma_tol=0.03,
                          capacity=int(N * 1.125))
    _ = plan.gamma        # score once: arms the γ-drift guard
    labels = np.full(plan.n, -1, np.int64)
    labels[:N] = labels0
    ndev = jax.device_count()
    sharded = api.shard(plan) if (sharded_too and ndev >= 2) else None

    # warmup: compile the streaming kernels (kNN subsets, quantized patch
    # scatters, γ scoring) outside the timed loop
    for s in range(WARM):
        kill, xin, lab = feed.batches(plan, labels, s, shape)
        plan, labels = _apply(plan, labels, kill, xin, lab)
        if sharded is not None:
            sharded = sharded.update(insert=xin, delete=kill)

    times = []
    for s in range(steps):
        kill, xin, lab = feed.batches(plan, labels, WARM + s, shape)
        t0 = time.perf_counter()
        plan2, labels = _apply(plan, labels, kill, xin, lab)
        jax.block_until_ready(plan2.bsr.vals)
        times.append(time.perf_counter() - t0)
        plan = plan2
        if sharded is not None:
            sharded = sharded.update(insert=xin, delete=kill)
    t_step = float(np.mean(times))        # amortizes compaction/restripe
    return plan, sharded, t_step


def _dbp_scenario(steps: int):
    """Uniform churn served through the double buffer: in-place tiers on
    the timed path, layout repairs (γ-rebucket / compact) on the daemon
    thread.

    The timed section of each step is the streaming update alone —
    apples-to-apples with the coherent scenario, which also times
    updates only. Steps right after a background build lands also pay
    the swap adoption and the queued-update replay inside that timed
    section, so the mean amortizes the whole maintenance protocol
    except the background build itself. An *untimed* serving matvec
    paces every step, so builds overlap real serving work and a
    mid-build matvec exercises the frozen old generation.

    Liveness is tracked from ``dbp.events`` — an ``("apply", ids)``
    extends the known-live set with the inserted physical slots, a
    compact ``("swap", ...)`` remaps it through ``compact_map`` — rather
    than from the plan's alive mask, which is frozen at the build's
    snapshot while a repair is in flight.
    """
    feed = _Stream(seed=2)
    x0, _ = feed.initial()
    # uniform churn scatters inserts over every row-block, so ELL slack —
    # not locality — is what absorbs them: slack 12 keeps overflow
    # restripes (synchronous by necessity) rare, and a looser γ tolerance
    # amortizes background rebuckets over several steps instead of
    # re-arming one per applied update
    plan = api.build_plan(x0, k=K, bs=32, sb=8, backend="bsr",
                          ell_slack=12, gamma_tol=0.06,
                          capacity=int(N * 1.125))
    _ = plan.gamma                     # score once: arms the γ-drift guard
    dbp = DoubleBufferedPlan(plan)
    live = np.arange(N)
    cursor = 0
    m = int(N * CHURN)
    counts = {"applied": 0, "queued": 0}

    def step():
        nonlocal live, cursor
        kill = feed.rng.choice(live, m, replace=False)
        xin = feed.arrivals(int(feed.rng.integers(0, N_CLUSTERS)), m)
        t0 = time.perf_counter()
        counts[dbp.update(insert=xin, delete=kill)] += 1
        dt = time.perf_counter() - t0
        # untimed serving tick: paces the loop while the build runs
        xv = jnp.asarray(feed.rng.standard_normal(dbp.plan.n), jnp.float32)
        jax.block_until_ready(dbp.matvec(xv))
        live = np.setdiff1d(live, kill, assume_unique=False)
        for ev in dbp.events[cursor:]:
            if ev[0] == "apply" and ev[1] is not None:
                live = np.concatenate([live, np.asarray(ev[1])])
            elif ev[0] == "swap" and ev[2] is not None:
                live = ev[2][live]     # compact renumbered the slots
        cursor = len(dbp.events)
        assert live.size and (live >= 0).all()
        return dt

    for _ in range(WARM):
        step()
    times = [step() for _ in range(steps)]
    return dbp, float(np.mean(times)), counts


def run(emit) -> None:
    rng = np.random.default_rng(1)

    # -- coherent churn: the acceptance scenario ---------------------------
    plan, sharded, t_step = _stream_scenario("coherent", STEPS,
                                             sharded_too=True)
    st = plan.refresh_stats
    x_live = plan.host.x[plan.alive]
    t_build = timeit(lambda: api.build_plan(x_live, config=plan.config),
                     warmup=1, iters=3)
    fresh = api.build_plan(x_live, config=plan.config)
    speedup = t_build / t_step
    gamma_ratio = plan.gamma / fresh.gamma

    emit(f"bench_stream/coherent_n{N}_step,{t_step*1e6:.0f},"
         f"appends={st.appends};tombstones={st.tombstones};"
         f"rebuckets={st.rebuckets};restripes={st.restripes};"
         f"compactions={st.compactions};grows={st.grows};"
         f"dead_frac={plan.dead_frac:.3f}")
    emit(f"bench_stream/coherent_n{N}_rebuild,{t_build*1e6:.0f},"
         f"speedup={speedup:.2f}x;gamma_ratio={gamma_ratio:.3f}")

    # ISSUE 4 acceptance: <=5% churn streams >=3x faster than rebuilding,
    # with gamma within 5% of a fresh build over the survivors
    assert speedup >= GATE_SPEEDUP, (
        f"streaming step {speedup:.2f}x < {GATE_SPEEDUP}x over build_plan "
        f"(step {t_step*1e3:.1f}ms vs build {t_build*1e3:.1f}ms)")
    assert abs(1.0 - gamma_ratio) <= GATE_GAMMA, (
        f"streamed gamma {plan.gamma:.3f} not within {GATE_GAMMA:.0%} of "
        f"fresh-build gamma {fresh.gamma:.3f}")

    # after compact: bit-exact against a fresh build on the survivors
    compacted = plan.compact()
    xv = jnp.asarray(rng.standard_normal(compacted.n), jnp.float32)
    y_c = np.asarray(compacted.matvec(xv))
    y_f = np.asarray(api.build_plan(x_live, config=plan.config).matvec(xv))
    assert np.array_equal(y_c, y_f), "compact diverged from a fresh build"
    emit(f"bench_stream/compact_n{compacted.n},,bit_exact=1")

    if sharded is not None:
        # the same streamed sequence on the mesh matches single-device
        xs = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
        y_sh = np.asarray(sharded.matvec(xs))
        y_1d = np.asarray(plan.matvec(xs, backend="bsr"))
        err = float(np.abs(y_sh - y_1d).max())
        assert err < 1e-3, (
            f"sharded streamed plan diverged from single-device: {err:.2e}")
        emit(f"bench_stream/sharded_dev{jax.device_count()},,err={err:.2e};"
             f"patches={sharded.shard_patches};reshards={sharded.reshards}")
    else:
        emit("bench_stream/sharded,skipped,reason=single_device")

    # -- uniform churn through the double buffer ---------------------------
    dbp, t_step_u, counts = _dbp_scenario(STEPS)
    plan_u = dbp.flush()
    if dbp.last_swap is None:          # quiet run: force one compact swap
        live_u = np.nonzero(plan_u.alive)[0]
        dbp.update(delete=live_u[: int(0.30 * live_u.size)])
        plan_u = dbp.flush()
    st_u = plan_u.refresh_stats
    n_swaps = sum(1 for e in dbp.events if e[0] == "swap")
    emit(f"bench_stream/uniform_dbp_n{N}_step,{t_step_u*1e6:.0f},"
         f"ratio_vs_coherent={t_step_u/t_step:.2f};"
         f"applied={counts['applied']};queued={counts['queued']};"
         f"generations={dbp.generation};swaps={n_swaps};"
         f"rebuckets={st_u.rebuckets};compactions={st_u.compactions};"
         f"restripes={st_u.restripes}")

    # ISSUE 8 acceptance: with layout maintenance off the critical path,
    # the worst-case churn shape stays within GATE_UNIFORM of coherent
    assert t_step_u <= GATE_UNIFORM * t_step, (
        f"uniform (double-buffered) step {t_step_u*1e3:.1f}ms exceeds "
        f"{GATE_UNIFORM}x the coherent step {t_step*1e3:.1f}ms")

    # swap bit-exactness: re-running the adopted repair synchronously on
    # its snapshot must reproduce the swapped-in successor exactly
    snapshot, successor, kind = dbp.last_swap
    redo = api.apply_pending_layout(snapshot)
    assert np.array_equal(np.asarray(successor.bsr.vals),
                          np.asarray(redo.bsr.vals)), (
        f"background {kind} swap diverged from the synchronous repair")
    xu = jnp.asarray(rng.standard_normal(successor.n), jnp.float32)
    assert np.array_equal(np.asarray(successor.matvec(xu)),
                          np.asarray(redo.matvec(xu))), (
        f"background {kind} swap matvec diverged")
    emit(f"bench_stream/uniform_swap_{kind},,bit_exact=1")


if __name__ == "__main__":
    run(print)
