"""Streaming churn: insert/delete tiers vs rebuilding the plan per step.

The ROADMAP's streaming item: growing/shrinking point sets must be served
by in-place append/tombstone tiers with an amortized compaction, not a
``build_plan`` per change. This suite streams sustained churn (<=5% of the
points inserted+deleted per step) through ``api.update_plan`` with two
churn shapes, mirroring bench_refresh's coherent/uniform split:

  coherent    one region's points retire and fresh arrivals replace them
              (a re-ingested shard / re-crawled region: deletions and
              insertions share leaves, so the streamed step patches a
              bounded set of row-blocks) — the ACCEPTANCE scenario:
              mean per-step wall time (amortized over any compactions /
              restripes the policy triggers) must be >=3x faster than a
              from-scratch ``build_plan`` on the survivors, with the
              streamed plan's γ (dead rows ignored) within 5% of a
              fresh build's
  uniform     churn scattered over the whole cloud — the in-place tiers'
              worst case (every row-block holds some edge of some
              deleted point, so the policy restripes the storage
              wholesale); reported, not asserted

Also asserted in-suite: after an explicit compact, matvec is bit-exact
against a fresh build over the surviving points; and on a >=2-device
mesh the same streamed sequence applied through ``ShardedPlan.update``
matches the single-device result.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_stream
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro import api

N, K, D = 16384, 16, 32
N_CLUSTERS = 16
CHURN = 0.025          # per side, per step  (insert + delete = 5%)
STEPS = 12
WARM = 6
GATE_SPEEDUP = 3.0
GATE_GAMMA = 0.05


class _Stream:
    """A mixture feed with per-point cluster labels, so churn can be
    regional (coherent) or global (uniform)."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)
        basis = self.rng.standard_normal((8, D)) / np.sqrt(8)
        self.centers = (self.rng.standard_normal((N_CLUSTERS, 8)) @ basis
                        * 3.0).astype(np.float32)

    def initial(self):
        labels = self.rng.integers(0, N_CLUSTERS, N)
        x = (self.centers[labels] + 0.5 * self.rng.standard_normal((N, D))
             ).astype(np.float32)
        return x, labels

    def arrivals(self, c: int, m: int) -> np.ndarray:
        return (self.centers[c]
                + 0.5 * self.rng.standard_normal((m, D))).astype(np.float32)

    def batches(self, plan, labels, step: int, shape: str):
        m = int(N * CHURN)
        live = np.nonzero(plan.alive)[0]
        if shape == "coherent":
            c = step % N_CLUSTERS
            mine = live[labels[live] == c]
            take = min(m, len(mine))
            kill = self.rng.choice(mine, take, replace=False)
            if take < m:
                rest = np.setdiff1d(live, kill, assume_unique=False)
                kill = np.concatenate(
                    [kill, self.rng.choice(rest, m - take, replace=False)])
            xin = self.arrivals(c, m)
            lab = c
        else:
            kill = self.rng.choice(live, m, replace=False)
            c = int(self.rng.integers(0, N_CLUSTERS))
            xin = self.arrivals(c, m)
            lab = c
        return kill, xin, lab


def _apply(plan, labels, kill, xin, lab):
    plan = api.update_plan(plan, insert=xin, delete=kill)
    if len(labels) != plan.n:         # capacity grew or plan compacted
        cmap = plan.host.compact_map
        new_labels = np.full(plan.n, -1, np.int64)
        if cmap is not None:
            surv = np.nonzero(cmap >= 0)[0]
            new_labels[cmap[surv]] = labels[surv]
        else:
            new_labels[:len(labels)] = labels
        labels = new_labels
    ids = plan.host.last_inserted_idx
    if ids is not None:
        labels[ids] = lab
    return plan, labels


def _stream_scenario(shape: str, steps: int, sharded_too: bool):
    feed = _Stream(seed=0)
    x0, labels0 = feed.initial()
    # capacity slack interleaves free slots through the leaves (inserts
    # land in place); gamma_tol=0.03: the γ-drift guard rebuckets (stable
    # code re-sort + build_bsr, no kNN) well inside the 5% gate margin
    plan = api.build_plan(x0, k=K, bs=32, sb=8, backend="bsr",
                          ell_slack=4, gamma_tol=0.03,
                          capacity=int(N * 1.125))
    _ = plan.gamma        # score once: arms the γ-drift guard
    labels = np.full(plan.n, -1, np.int64)
    labels[:N] = labels0
    ndev = jax.device_count()
    sharded = api.shard(plan) if (sharded_too and ndev >= 2) else None

    # warmup: compile the streaming kernels (kNN subsets, quantized patch
    # scatters, γ scoring) outside the timed loop
    for s in range(WARM):
        kill, xin, lab = feed.batches(plan, labels, s, shape)
        plan, labels = _apply(plan, labels, kill, xin, lab)
        if sharded is not None:
            sharded = sharded.update(insert=xin, delete=kill)

    times = []
    for s in range(steps):
        kill, xin, lab = feed.batches(plan, labels, WARM + s, shape)
        t0 = time.perf_counter()
        plan2, labels = _apply(plan, labels, kill, xin, lab)
        jax.block_until_ready(plan2.bsr.vals)
        times.append(time.perf_counter() - t0)
        plan = plan2
        if sharded is not None:
            sharded = sharded.update(insert=xin, delete=kill)
    t_step = float(np.mean(times))        # amortizes compaction/restripe
    return plan, sharded, t_step


def run(emit) -> None:
    rng = np.random.default_rng(1)

    # -- coherent churn: the acceptance scenario ---------------------------
    plan, sharded, t_step = _stream_scenario("coherent", STEPS,
                                             sharded_too=True)
    st = plan.refresh_stats
    x_live = plan.host.x[plan.alive]
    t_build = timeit(lambda: api.build_plan(x_live, config=plan.config),
                     warmup=1, iters=3)
    fresh = api.build_plan(x_live, config=plan.config)
    speedup = t_build / t_step
    gamma_ratio = plan.gamma / fresh.gamma

    emit(f"bench_stream/coherent_n{N}_step,{t_step*1e6:.0f},"
         f"appends={st.appends};tombstones={st.tombstones};"
         f"rebuckets={st.rebuckets};restripes={st.restripes};"
         f"compactions={st.compactions};grows={st.grows};"
         f"dead_frac={plan.dead_frac:.3f}")
    emit(f"bench_stream/coherent_n{N}_rebuild,{t_build*1e6:.0f},"
         f"speedup={speedup:.2f}x;gamma_ratio={gamma_ratio:.3f}")

    # ISSUE 4 acceptance: <=5% churn streams >=3x faster than rebuilding,
    # with gamma within 5% of a fresh build over the survivors
    assert speedup >= GATE_SPEEDUP, (
        f"streaming step {speedup:.2f}x < {GATE_SPEEDUP}x over build_plan "
        f"(step {t_step*1e3:.1f}ms vs build {t_build*1e3:.1f}ms)")
    assert abs(1.0 - gamma_ratio) <= GATE_GAMMA, (
        f"streamed gamma {plan.gamma:.3f} not within {GATE_GAMMA:.0%} of "
        f"fresh-build gamma {fresh.gamma:.3f}")

    # after compact: bit-exact against a fresh build on the survivors
    compacted = plan.compact()
    xv = jnp.asarray(rng.standard_normal(compacted.n), jnp.float32)
    y_c = np.asarray(compacted.matvec(xv))
    y_f = np.asarray(api.build_plan(x_live, config=plan.config).matvec(xv))
    assert np.array_equal(y_c, y_f), "compact diverged from a fresh build"
    emit(f"bench_stream/compact_n{compacted.n},,bit_exact=1")

    if sharded is not None:
        # the same streamed sequence on the mesh matches single-device
        xs = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
        y_sh = np.asarray(sharded.matvec(xs))
        y_1d = np.asarray(plan.matvec(xs, backend="bsr"))
        err = float(np.abs(y_sh - y_1d).max())
        assert err < 1e-3, (
            f"sharded streamed plan diverged from single-device: {err:.2e}")
        emit(f"bench_stream/sharded_dev{jax.device_count()},,err={err:.2e};"
             f"patches={sharded.shard_patches};reshards={sharded.reshards}")
    else:
        emit("bench_stream/sharded,skipped,reason=single_device")

    # -- uniform churn: worst case, reported not asserted ------------------
    plan_u, _, t_step_u = _stream_scenario("uniform", 6,
                                           sharded_too=False)
    st_u = plan_u.refresh_stats
    emit(f"bench_stream/uniform_n{N}_step,{t_step_u*1e6:.0f},"
         f"speedup={t_build/t_step_u:.2f}x;restripes={st_u.restripes};"
         f"rebuckets={st_u.rebuckets};compactions={st_u.compactions}")


if __name__ == "__main__":
    run(print)
