"""Benchmark harness: one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV lines.

  fig1_orderings   paper Fig. 1  (beta/gamma, four orderings)
  table1_gamma     paper Table 1 (gamma across orderings, SIFT/GIST-like)
  fig3_throughput  paper Fig. 3  (interaction throughput per ordering)
  micro_blas       paper §4.1    (banded best case vs scattered base case)
  attention_bench  beyond-paper  (cluster-sparse vs dense attention)
  bench_refresh    beyond-paper  (plan refresh vs rebuild, §3.2 drift)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from benchmarks import (attention_bench, bench_refresh, fig1_orderings,
                            fig3_throughput, micro_blas, table1_gamma)
    suites = {
        "fig1_orderings": fig1_orderings.run,
        "table1_gamma": table1_gamma.run,
        "fig3_throughput": fig3_throughput.run,
        "micro_blas": micro_blas.run,
        "attention_bench": attention_bench.run,
        "bench_refresh": bench_refresh.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    unknown = [c for c in chosen if c not in suites]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(suites)}")

    print("name,us_per_call,derived")
    for name in chosen:
        t0 = time.time()
        suites[name](lambda line: print(line, flush=True))
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
