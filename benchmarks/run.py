"""Benchmark harness: one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV lines; ``--json out.json``
additionally records every line as a structured result (plus environment
metadata) so CI can upload the numbers as an artifact and later PRs can
diff them — the bench trajectory convention is ``BENCH_plan.json``.

  fig1_orderings   paper Fig. 1  (beta/gamma, four orderings)
  table1_gamma     paper Table 1 (gamma across orderings, SIFT/GIST-like)
  fig3_throughput  paper Fig. 3  (interaction throughput per ordering)
  micro_blas       paper §4.1    (banded best case vs scattered base case)
  attention_bench  beyond-paper  (cluster-sparse vs dense attention)
  bench_refresh    beyond-paper  (plan refresh vs rebuild, §3.2 drift)
  bench_shard      beyond-paper  (halo-exchange sharded matvec vs bsr)
  bench_stream     beyond-paper  (insert/delete churn vs rebuild-per-step)
  bench_batch      beyond-paper  (PlanBatch vmapped matvec vs plan loop)
  bench_serve      beyond-paper  (decode service vs per-call Morton sort)
  bench_kernels    beyond-paper  (analytic cost model vs probe ranking,
                                  batched Pallas bit-parity)
  bench_solvers    beyond-paper  (batched block-Jacobi CG vs plain CG
                                  vs per-plan eager solve loop)

Gated suites assert their acceptance in-suite; a failed gate is recorded
per suite (the remaining suites still run, the JSON artifact carries the
failure) and the process exits non-zero — a red gate can no longer hide
behind a green artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def merge(out: str, parts: list) -> None:
    """Combine several ``--json`` outputs into one trajectory file (CI
    runs suites under different env/mesh settings, then uploads one
    ``BENCH_plan.json`` artifact). Accepts both single-run docs
    (``env``) and already-merged docs (``envs``), so trajectories can be
    extended; each result is stamped with its run's device_count so the
    mesh context survives the flattening."""
    docs = [json.load(open(p)) for p in parts]
    suites, envs, results = [], [], []
    gate_failures = {}
    for d in docs:
        suites += d["suites"]
        part_envs = d.get("envs") or [d["env"]]
        envs += part_envs
        gate_failures.update(d.get("gate_failures") or {})
        dev = (part_envs[0].get("device_count")
               if len(part_envs) == 1 else None)
        for r in d["results"]:
            if dev is not None and "device_count" not in r:
                r = {**r, "device_count": dev}
            results.append(r)
    combined = {"schema": 1, "suites": suites, "envs": envs,
                "gate_failures": gate_failures, "results": results}
    with open(out, "w") as f:
        json.dump(combined, f, indent=2)
    print(f"# merged {len(parts)} files -> {out} "
          f"({len(results)} results)", file=sys.stderr)
    if gate_failures:
        # the merged artifact records the failures AND the merge step
        # itself goes red — a failed gate cannot ride a green upload
        for name, msg in gate_failures.items():
            print(f"# GATE FAILED {name}: {msg}", file=sys.stderr)
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write results as structured JSON to OUT")
    ap.add_argument("--merge", nargs="+", default=None,
                    metavar=("OUT", "IN"),
                    help="merge JSON result files: OUT IN [IN ...]")
    args = ap.parse_args()

    if args.merge:
        if len(args.merge) < 2:
            ap.error("--merge needs OUT and at least one IN file")
        merge(args.merge[0], args.merge[1:])
        return

    from benchmarks import (attention_bench, bench_batch, bench_kernels,
                            bench_refresh, bench_serve, bench_shard,
                            bench_solvers, bench_stream, fig1_orderings,
                            fig3_throughput, micro_blas, table1_gamma)
    suites = {
        "fig1_orderings": fig1_orderings.run,
        "table1_gamma": table1_gamma.run,
        "fig3_throughput": fig3_throughput.run,
        "micro_blas": micro_blas.run,
        "attention_bench": attention_bench.run,
        "bench_refresh": bench_refresh.run,
        "bench_shard": bench_shard.run,
        "bench_stream": bench_stream.run,
        "bench_batch": bench_batch.run,
        "bench_serve": bench_serve.run,
        "bench_kernels": bench_kernels.run,
        "bench_solvers": bench_solvers.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    unknown = [c for c in chosen if c not in suites]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(suites)}")

    results = []

    def emit(line: str) -> None:
        print(line, flush=True)
        name, us, derived = (line.split(",", 2) + ["", ""])[:3]
        try:
            us_val = float(us)      # some suites emit "skipped" here
        except ValueError:
            us_val = None
        rec = {"name": name, "us_per_call": us_val}
        # derived is a ;-separated key=value bag (backend, speedup, ...)
        for kv in filter(None, derived.split(";")):
            k, _, v = kv.partition("=")
            rec[k] = v
        results.append(rec)

    gate_failures = {}
    print("name,us_per_call,derived")
    for name in chosen:
        t0 = time.time()
        try:
            suites[name](emit)
        except AssertionError as e:
            # an in-suite gate failed: record it, keep running the other
            # suites, and exit non-zero at the end so the run (and any
            # artifact built from it) is visibly red
            gate_failures[name] = str(e)
            print(f"# GATE FAILED {name}: {e}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        import platform

        import jax

        doc = {
            "schema": 1,
            "suites": chosen,
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "python": platform.python_version(),
            },
            "gate_failures": gate_failures,
            "results": results,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(results)} results to {args.json}",
              file=sys.stderr)

    if gate_failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
