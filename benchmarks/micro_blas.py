"""Paper §4.1 micro-benchmarks: banded (best case) vs scattered (base case)
block-sparse SpMV at fixed size and nnz — the machine-specific reference
the paper compares its orderings against. Runs through the plan API's
backend registry (jnp block paths + the Pallas kernel, interpret on CPU)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro import api
from repro.core.blocksparse import random_bsr
from repro.core import interact


def run(out):
    n, bs, nbr = 8192, 32, 16
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    for case, banded in [("banded", True), ("scattered", False)]:
        plan = api.InteractionPlan.from_bsr(
            random_bsr(0, n, bs, nbr, sb=8, banded=banded))
        t_flat = timeit(lambda: plan.apply(x, backend="bsr"))
        t_ml = timeit(lambda: plan.apply(x, backend="bsr_ml"))
        out(f"micro_{case}_bsr,{t_flat*1e6:.0f},n={n};bs={bs};nbr={nbr}")
        out(f"micro_{case}_bsr_ml,{t_ml*1e6:.0f},superblock_schedule")
        # Pallas path: correctness only on CPU (interpret mode is a Python
        # emulator — wall time is meaningless; see tests/test_kernels.py)
        y_pal = plan.apply(x, backend="pallas")
        err = float(jnp.abs(y_pal - plan.apply(x, backend="bsr")).max())
        out(f"micro_{case}_pallas_check,{err:.2e},interpret_allclose")
    # CSR gather reference at matched nnz
    rng = np.random.default_rng(1)
    nnz = (n // bs) * nbr * bs * bs
    rows = jnp.asarray(rng.integers(0, n, nnz), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n, nnz), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(nnz), jnp.float32)
    t_csr = timeit(lambda: interact.spmv_csr(vals, rows, cols, x, n))
    out(f"micro_scattered_csr,{t_csr*1e6:.0f},nnz={nnz}")
