"""Beyond-paper: cluster-sparse attention vs dense flash attention (CPU
wall-clock at small scale + the flop model at production scale). The LM-side
analog of Fig. 3: the same reordering machinery applied to attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.configs.base import ClusterKVConfig
from repro.models import attention as attn
from repro.launch.analytic import cell_model


def run(out):
    B, Hq, Hkv, S, dh = 1, 8, 2, 2048, 64
    rng = np.random.default_rng(0)
    cc = rng.standard_normal((8, dh)) * 4
    asg = rng.integers(0, 8, S)
    k = jnp.asarray(cc[asg] + 0.3 * rng.standard_normal((S, dh)),
                    jnp.float32)[None, None].repeat(Hkv, 1)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, dh)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)

    t_flash = timeit(lambda: attn.flash_attention(q, k, v, pos, pos),
                     warmup=1, iters=5)
    out(f"attn_dense_flash_s2048,{t_flash*1e6:.0f},B1H8")
    for nb in (4, 8, 16):
        cfg = ClusterKVConfig(enabled=True, block_q=128, block_k=128,
                              blocks_per_query=nb)
        t_ck = timeit(lambda: attn.clusterkv_attention(q, k, v, pos, pos,
                                                       cfg), warmup=1, iters=5)
        out(f"attn_clusterkv_b{nb}_s2048,{t_ck*1e6:.0f},"
            f"x{t_flash/t_ck:.2f}_vs_flash")

    # production-scale flop model (mistral-large prefill_32k)
    dense = cell_model("mistral-large-123b", "prefill_32k", "flash")
    ck = cell_model("mistral-large-123b", "prefill_32k", "clusterkv")
    out(f"attn_model_mistral_prefill32k_dense,{dense.flops:.3e},global_flops")
    out(f"attn_model_mistral_prefill32k_clusterkv,{ck.flops:.3e},"
        f"x{dense.flops/ck.flops:.2f}_fewer")
