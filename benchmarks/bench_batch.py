"""Batched plans: one vmapped kernel vs a python loop over single plans.

The ROADMAP's batched-plans item: many small problems in lockstep (one
plan per attention head / batch entry, clusterkv-style) must be served by
ONE compiled kernel over stacked ``PlanData`` — not a python loop that
pays a dispatch (and, for heterogeneous hosts, a retrace) per plan. This
suite builds ``B`` small plans on distinct clustered clouds, stacks them
into a ``PlanBatch``, and measures:

  batched     ``batch.matvec(xs)`` — one vmapped kernel (the acceptance
              path). GATES: >= 5x faster than the loop below, AND the
              kernel traces exactly once for the whole batch (counted via
              an instrumented backend).
  loop        ``[p.matvec(x) for p in members]`` — the pre-PlanBatch
              reality: B separate dispatches through the single-plan API
              (the per-plan kernels are shape-shared and compile once;
              the loop's cost is pure dispatch + small-kernel overhead,
              i.e. the *best case* for the loop).
  lockstep    one streamed insert+delete step through every member
              (reported): per-plan tier escalation, one shared re-spec.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_batch
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro import api
from repro.core import registry
from repro.data.pipeline import feature_mixture

B, N, D, K = 64, 1024, 32, 8
GATE_SPEEDUP = 5.0


def run(emit) -> None:
    rng = np.random.default_rng(0)
    xs = [feature_mixture(N, D, n_clusters=32, seed=s) for s in range(B)]
    batch = api.build_plan_batch(xs, k=K, bs=16, sb=8, backend="bsr")
    charges = jnp.asarray(
        rng.standard_normal((B, batch.capacity)), jnp.float32)

    # -- one-compilation gate: the batched kernel must trace exactly once
    calls = []

    @api.register_backend("bench_batch_counter")
    def _counting(p, x, **kw):
        calls.append(1)
        return api.get_backend("bsr")(p, x)

    try:
        jax.block_until_ready(
            batch.matvec(charges, backend="bench_batch_counter"))
        jax.block_until_ready(
            batch.matvec(charges, backend="bench_batch_counter"))
        n_traces = len(calls)
    finally:
        registry._BACKENDS.pop("bench_batch_counter", None)
    assert n_traces == 1, (
        f"batched matvec traced {n_traces}x for a batch of {B}; the "
        "PlanBatch contract is ONE compilation for the whole batch")

    # -- batched vs loop --------------------------------------------------
    t_batched = timeit(lambda: batch.matvec(charges), warmup=2, iters=10)

    members = batch.members()           # single-plan views, built once

    def loop():
        return [m.matvec(charges[i]) for i, m in enumerate(members)]

    t_loop = timeit(lambda: jax.block_until_ready(loop()),
                    warmup=2, iters=10)
    speedup = t_loop / t_batched

    emit(f"bench_batch/batched_B{B}_n{N},{t_batched*1e6:.0f},"
         f"traces={n_traces};backend=bsr")
    emit(f"bench_batch/loop_B{B}_n{N},{t_loop*1e6:.0f},"
         f"speedup={speedup:.2f}x")

    # correctness alongside the numbers: the two paths agree
    y_b = np.asarray(batch.matvec(charges))
    y_l = np.stack([np.asarray(y) for y in loop()])
    err = float(np.abs(y_b - y_l).max())
    assert err < 1e-4, f"batched vs loop disagreement {err:.2e}"

    # ISSUE 5 acceptance: batched matvec over 64 plans of n=1024 must be
    # >= 5x a python loop over the single plans, with one compilation
    assert speedup >= GATE_SPEEDUP, (
        f"batched matvec {speedup:.2f}x < {GATE_SPEEDUP}x over the "
        f"single-plan loop (batched {t_batched*1e3:.2f}ms vs loop "
        f"{t_loop*1e3:.2f}ms)")

    # -- lockstep streaming step (reported, not gated) ---------------------
    sbatch = api.build_plan_batch(xs[:8], k=K, bs=16, sb=8, backend="bsr",
                                  ell_slack=4, capacity=N + 128)
    kills = [rng.choice(N, 16, replace=False) for _ in range(8)]
    arrivals = [feature_mixture(16, D, n_clusters=32, seed=100 + i)
                for i in range(8)]
    import time as _time
    sbatch.update(insert=arrivals, delete=kills)      # warm the kernels
    t0 = _time.perf_counter()
    s2 = sbatch.update(insert=arrivals, delete=kills)
    jax.block_until_ready(s2.data.vals)
    t_step = _time.perf_counter() - t0
    emit(f"bench_batch/lockstep_B8_n{N},{t_step*1e6:.0f},"
         f"spec_stable={int(s2.spec == sbatch.spec)}")


if __name__ == "__main__":
    run(print)
