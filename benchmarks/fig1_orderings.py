"""Paper Fig. 1: beta and gamma for four orderings of a 500x500 block-
arrowhead matrix (full 20x20 blocks). Reproduces the claim that (a) and (b)
are equivalent (principled equivalence) and (c), (d) degrade."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import measures


def arrowhead(n=500, b=20):
    rows, cols = [], []
    nb = n // b
    for k in range(nb):
        r0 = k * b
        ii, jj = np.meshgrid(np.arange(b), np.arange(b), indexing="ij")
        rows.append(r0 + ii.ravel())
        cols.append(r0 + jj.ravel())
        if k > 0:
            rows.append(ii.ravel())
            cols.append(r0 + jj.ravel())
            rows.append(r0 + ii.ravel())
            cols.append(jj.ravel())
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    key = rows.astype(np.int64) * n + cols
    _, first = np.unique(key, return_index=True)
    return rows[first], cols[first]


def run(out):
    n, b = 500, 20
    rows, cols = arrowhead(n, b)
    rng = np.random.default_rng(0)
    pb = rng.permutation(n // b)
    perm_block = np.concatenate([np.arange(b) + b * p for p in pb])
    perm_rows = rng.permutation(n)
    perm_cols = rng.permutation(n)

    def apply(perm, idx):
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        return inv[idx]

    cases = {
        "a_arrowhead": (rows, cols),
        "b_block_perm": (apply(perm_block, rows), apply(perm_block, cols)),
        "c_row_perm": (apply(perm_rows, rows), cols),
        "d_row_col_perm": (apply(perm_rows, rows), apply(perm_cols, cols)),
    }
    for name, (r, c) in cases.items():
        beta = measures.beta_estimate(r, c, n)
        gamma = float(measures.gamma_score(jnp.asarray(r), jnp.asarray(c),
                                           10.0, n))
        out(f"fig1_{name}_beta,{beta['beta']:.6f},block={beta['block']}")
        out(f"fig1_{name}_gamma,{gamma:.4f},sigma=10")
