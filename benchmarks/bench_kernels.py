"""Kernel-backend calibration: the analytic cost model vs the stopwatch.

The analytic-first autotune (``core.costmodel`` + ``core.autotune``) ranks
SpMV backends from a closed-form flops/bytes model and uses probes only to
calibrate constants. This suite keeps the model honest against hardware
truth on four calibration shapes spanning the planner's envelope (small /
medium / wide-block / large), and pins the Pallas batch-grid kernel's
bit-parity contract alongside the numbers:

  shapes      per shape: measured probe ranking (``autotune
              .probe_backends``) vs uncalibrated analytic ranking
              (``costmodel.rank_backends`` fed the true COO edge count —
              the shapes span block-fill regimes, so the blocked-vs-
              per-edge crossover is exactly what the model must get
              right). GATE: the two rankings agree (same winner) on
              >= 3 of the 4 shapes — a model that picks the wrong
              backend on the actual machine must go red here, not
              silently misroute ``backend="auto"``.
  auto        ``tune_backend`` end-to-end on the medium shape: probes are
              demoted to calibration, the memoized decision carries the
              machine-readable ``repro.cost/v1`` ranking report.
  parity      batched Pallas kernel (interpret mode on CPU) vs the
              ``bsr_ml`` batched path on a capacity-padded batch with
              streaming holes. GATE: bitwise equal, not approx.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_kernels
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.core import autotune, costmodel

# (label, n, bs, sb, k, f) — the four calibration shapes
SHAPES = [
    ("small_n256_bs16", 256, 16, 4, 8, 1),
    ("medium_n1024_bs16", 1024, 16, 8, 8, 1),
    ("wide_n1024_bs32_f8", 1024, 32, 8, 8, 8),
    ("large_n4096_bs32", 4096, 32, 16, 8, 1),
]
BACKENDS = ("csr", "bsr", "bsr_ml")
GATE_AGREE = 3


def _plan(n, bs, sb, k, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    return api.build_plan(pts, k=k, bs=bs, sb=sb, backend="bsr")


def run(emit) -> None:
    rng = np.random.default_rng(0)
    autotune.clear_tune_memo()
    autotune.clear_calibration()

    # -- per-shape: measured probe ranking vs analytic ranking -------------
    agree = 0
    for i, (label, n, bs, sb, k, f) in enumerate(SHAPES):
        plan = _plan(n, bs, sb, k, seed=i)
        shape = (plan.n,) if f == 1 else (plan.n, f)
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        measured = autotune.probe_backends(plan, x, backends=BACKENDS,
                                           warmup=1, iters=3)
        feat = costmodel.plan_features(plan.spec.shape_key, f=f,
                                       nnz=len(plan.coo[0]))
        report = costmodel.rank_backends(feat, tuple(measured))
        m_rank = sorted(measured, key=measured.get)
        a_rank = report["ranking"]
        ok = bool(m_rank and a_rank and m_rank[0] == a_rank[0])
        agree += ok
        best = m_rank[0] if m_rank else "none"
        emit(f"bench_kernels/{label},{measured.get(best, 0) * 1e6:.0f},"
             f"measured={best};analytic={report['winner']};agree={int(ok)}")

    emit(f"bench_kernels/ranking_gate,skipped,agree={agree}/{len(SHAPES)}")
    assert agree >= GATE_AGREE, (
        f"analytic ranking agrees with the measured probe ranking on only "
        f"{agree}/{len(SHAPES)} calibration shapes (need >= {GATE_AGREE}); "
        "the cost model no longer reflects this hardware — recalibrate the "
        "HardwareConfig knobs (gather_penalty / launch_overhead)")

    # -- auto resolution end-to-end: model decides, probes calibrate -------
    plan = _plan(*SHAPES[1][1:5], seed=1)
    t0 = time.perf_counter()
    winner, times = autotune.tune_backend(plan, device_count=1)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    winner2, _ = autotune.tune_backend(plan, device_count=1)  # memo hit
    t_hit = time.perf_counter() - t0
    (memo_report,) = [r for r in autotune._TUNE_MEMO.values()
                      if r.get("kind") == "backend_rank"][:1]
    assert winner2 == winner == memo_report["winner"]
    assert memo_report["schema"] == costmodel.SCHEMA
    emit(f"bench_kernels/auto_medium,{t_first * 1e6:.0f},"
         f"winner={winner};memo_hit_us={t_hit * 1e6:.0f};"
         f"ranked={len(times)}")

    # -- batched Pallas parity: capacity padding + streaming holes ---------
    pts = [rng.standard_normal((120, 8)).astype(np.float32)
           for _ in range(4)]
    pb = api.build_plan_batch(pts, k=8, bs=16, sb=4, backend="bsr",
                              ell_slack=4, capacity=128)
    pb = pb.delete([rng.choice(120, 17, replace=False) for _ in range(4)])
    xs = jnp.asarray(
        rng.standard_normal((pb.batch, pb.capacity)), jnp.float32)
    want = np.asarray(jax.block_until_ready(
        api._batch_apply_kernel(pb.spec, pb.data, xs, "bsr_ml", "apply")))
    t0 = time.perf_counter()
    got = np.asarray(jax.block_until_ready(
        api._batch_apply_kernel(pb.spec, pb.data, xs, "pallas", "apply")))
    t_pallas = time.perf_counter() - t0
    bit_equal = bool(np.array_equal(got, want))
    emit(f"bench_kernels/parity_batched_B4,{t_pallas * 1e6:.0f},"
         f"bit_equal={int(bit_equal)};holes=17")
    assert bit_equal, (
        "batched pallas backend is not bit-identical to bsr_ml on a "
        "capacity-padded batch with streaming holes")


if __name__ == "__main__":
    run(print)
