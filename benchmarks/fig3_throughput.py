"""Paper Fig. 3: iterative near-neighbor interaction (t-SNE attractive
force) throughput under each ordering.

Two execution paths per ordering:
  csr   gather-based per-edge interaction (what a scattered layout forces)
  bsr   blockwise-dense interaction over the ELL-BSR tiles (only viable
        when the ordering concentrates nonzeros into dense tiles — the
        paper's point; tile fill ratios are reported alongside)

The reference time (paper's convention) is the scattered CSR time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import knn_problem, timeit
from repro import api


CASES = [("sift", 4096, 30), ("gist", 2048, 45)]
ORDERINGS = ["scattered", "rcm", "pca_1d", "lex3", "dual_tree"]


def tsne_edge_path(rows, cols, p_vals, y, n):
    """Per-edge (CSR-style) attractive force — the gather baseline."""
    diff = y[rows] - y[cols]
    q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=1))
    w = (p_vals * q)[:, None] * diff
    return jnp.zeros_like(y).at[rows].add(w)


def run(out):
    for ds, n, k in CASES:
        x, rows, cols = knn_problem(ds, n, k)
        rng = np.random.default_rng(0)
        y_embed = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
        p_raw = rng.random(len(rows)).astype(np.float32)

        edge = jax.jit(tsne_edge_path, static_argnames=("n",))
        ref_time = None
        for name in ORDERINGS:
            plan = api.InteractionPlan.from_coo(rows, cols, p_raw, n, x=x,
                                                ordering=name, bs=32, sb=8)
            r2, c2, _ = plan.coo
            rj, cj = jnp.asarray(r2), jnp.asarray(c2)
            pv = jnp.asarray(p_raw)
            t_csr = timeit(lambda: edge(rj, cj, pv, y_embed, n))
            if ref_time is None:
                ref_time = t_csr
            line = f"fig3_{ds}_{name}_csr,{t_csr*1e6:.0f},x{ref_time/t_csr:.2f}"
            out(line)
            # blockwise path: only meaningful when tiles are dense enough.
            # kept-tile count == the paper's covering size == the MXU work
            # a TPU would do — the direct TPU-time proxy for this ordering.
            kept = int(np.asarray(plan.bsr.nbr_mask).sum())
            if plan.bsr.max_nbr * plan.bsr.bs <= 16 * k:  # scattered guard
                t_bsr = timeit(lambda: plan.tsne_attractive(y_embed))
                out(f"fig3_{ds}_{name}_bsr,{t_bsr*1e6:.0f},"
                    f"fill={plan.fill:.3f};tiles={kept};"
                    f"x{ref_time/t_bsr:.2f}")
            else:
                out(f"fig3_{ds}_{name}_bsr,skipped,"
                    f"fill={plan.fill:.3f};tiles={kept};tiles_too_sparse")
