"""ClusterKV decode service vs the per-call Morton-sort decode path.

The service thesis: at serving time the cluster ordering of a session's
keys is PLAN STATE, not something to re-derive per token. The per-call
clusterkv decode (``mode="percall"``) re-sorts every slot's cache and
recomputes every centroid inside each decode step; the service
(``mode="plan"``) builds each session's per-layer ``PlanBatch`` once at
admission and insert-streams generated keys into it, so a decode tick is
one scatter + one tile refresh + the sparse attend.

Both modes run the SAME continuous-batching engine over the same request
trace: ``SLOTS`` concurrent sessions with churn (more requests than
slots, mixed prompt lengths, so slots retire and backfill mid-run).

GATES (ISSUE 6): with >= 8 concurrent sessions under churn,
  - service tokens/sec >= 3x the per-call path;
  - the service compiles exactly ONE decode kernel across all admissions
    (trace count asserted, not eyeballed).

GATE (ISSUE 9): the vectorized host claim pass (``claim_slots_batched``
over all L*B*H members, with the inserter's maintained block maxima)
is >= 5x faster than the per-member ``claim_slot`` loop it replaced, at
the serve shape (8 sessions x max_seq 8192). The plan-mode report also
splits each tick into ``device_tick_s`` (jitted decode+land dispatch)
vs ``host_claim_s`` (inserter claim-and-mutate) so the kernel-bound
claim is measurable, not asserted from vibes.

  PYTHONPATH=src:. python benchmarks/run.py --only bench_serve
"""
from __future__ import annotations

import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig

SLOTS = 8
N_REQ = 16            # churn: every slot retires + backfills at least once
MAX_SEQ = 8192        # percall pays O(S) sort+permute+centroids per tick;
                      # the service's decode cost is capacity-independent
MAX_NEW = 64
GATE_SPEEDUP = 3.0
GATE_CLAIM = 5.0      # batched host claim vs the per-member loop


def _requests(cfg, rng, rid0=0):
    from repro.train.serve_loop import Request

    lengths = rng.integers(128, 256, size=N_REQ)
    return [Request(rid=rid0 + i,
                    tokens=rng.integers(0, cfg.vocab, int(n)
                                        ).astype(np.int32),
                    max_new=MAX_NEW)
            for i, n in enumerate(lengths)]


def _drive(cfg, params, mode):
    """One long-lived engine per mode: the first request wave warms every
    compile, then the meters reset and a second wave measures steady
    serving. Trace counters span BOTH waves — 2*N_REQ admissions must
    share one decode kernel."""
    from repro.serve import ClusterKVEngine

    engine = ClusterKVEngine(cfg, params, slots=SLOTS, max_seq=MAX_SEQ,
                             prefill_bucket=256, mode=mode)
    rng = np.random.default_rng(0)
    for r in _requests(cfg, rng):
        engine.submit(r)
    engine.run()
    engine.tokens_out, engine._tick_time = 0, 0.0   # keep traces, drop warmup
    engine._claim_time = engine._device_time = 0.0
    for r in _requests(cfg, rng, rid0=N_REQ):
        engine.submit(r)
    engine.run()
    return engine.report()


def _claim_bench(emit) -> None:
    """ISSUE 9 gate: the stacked claim pass vs the per-member loop it
    replaced, exercised exactly as the inserter drives it (in-order
    code/alive mirrors plus maintained block maxima) under tick churn at
    the serve shape."""
    import time

    from repro.serve.streaming import (CLAIM_BLOCK, claim_slot,
                                       claim_slots_batched)

    layers, heads, ticks = 2, 2, 64
    m = layers * SLOTS * heads
    rng = np.random.default_rng(0)
    base_codes = np.sort(
        rng.integers(0, 1 << 30, (m, MAX_SEQ)).astype(np.uint64), axis=1)
    base_alive = rng.random((m, MAX_SEQ)) < 0.5
    arrivals = rng.integers(0, 1 << 30, (ticks, m)).astype(np.uint64)

    class _Host:                       # claim_slot's duck-typed host view
        __slots__ = ("pi", "codes", "alive")

    hosts = []
    for i in range(m):
        h = _Host()
        h.pi = np.arange(MAX_SEQ)
        h.codes = base_codes[i].copy()
        h.alive = base_alive[i].copy()
        hosts.append(h)
    t0 = time.time()
    loop_phys = np.zeros((ticks, m), np.int64)
    for t in range(ticks):
        for i, h in enumerate(hosts):
            p = claim_slot(h, arrivals[t, i])
            h.alive[p] = True
            h.codes[p] = arrivals[t, i]
            loop_phys[t, i] = p
    t_loop = time.time() - t0

    ci, ai = base_codes.copy(), base_alive.copy()
    bm = ci.reshape(m, -1, CLAIM_BLOCK).max(axis=2)
    rows = np.arange(m)
    t0 = time.time()
    vec_phys = np.zeros((ticks, m), np.int64)
    for t in range(ticks):
        pos = claim_slots_batched(ci, ai, arrivals[t], block_max=bm)
        ai[rows, pos] = True
        ci[rows, pos] = arrivals[t]
        blk = pos // CLAIM_BLOCK
        seg = ci[rows[:, None],
                 (blk * CLAIM_BLOCK)[:, None] + np.arange(CLAIM_BLOCK)]
        bm[rows, blk] = seg.max(axis=1)
        vec_phys[t] = pos
    t_vec = time.time() - t0

    assert (vec_phys == loop_phys).all(), (
        "batched claims diverged from the per-member claim_slot loop")
    ratio = t_loop / max(t_vec, 1e-9)
    emit(f"bench_serve/host_claim_m{m}_cap{MAX_SEQ},"
         f"{t_vec / ticks * 1e6:.0f},"
         f"loop_us={t_loop / ticks * 1e6:.0f};speedup={ratio:.1f}x")
    assert ratio >= GATE_CLAIM, (
        f"batched host claim {ratio:.2f}x < {GATE_CLAIM}x over the "
        f"per-member loop ({t_vec * 1e3:.1f}ms vs {t_loop * 1e3:.1f}ms "
        f"for {ticks} ticks x {m} members)")


def run(emit) -> None:
    import jax

    from repro.models import model_api

    # float32: the CPU-performant dtype for BOTH paths (bf16 scatter and
    # gather are emulated elementwise on CPU and would distort the ratio)
    cfg = reduced_config("qwen2-0.5b").with_(
        dtype="float32",
        clusterkv=ClusterKVConfig(enabled=True, block_q=128, block_k=128,
                                  blocks_per_query=4, decode_clusters=4))
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))

    reports = {}
    for mode in ("percall", "plan"):
        _drive(cfg, params, mode)              # warm the compile cache
        reports[mode] = _drive(cfg, params, mode)

    plan, percall = reports["plan"], reports["percall"]
    speedup = plan["tokens_per_sec"] / max(percall["tokens_per_sec"], 1e-9)
    for mode, rep in reports.items():
        us = 1e6 / max(rep["tokens_per_sec"], 1e-9)     # us per token
        emit(f"bench_serve/{mode}_s{SLOTS}_seq{MAX_SEQ},{us:.0f},"
             f"tok_s={rep['tokens_per_sec']:.1f};ticks={rep['ticks']};"
             f"decode_traces={rep['decode_traces']}")
    emit(f"bench_serve/service_speedup,{0:.0f},"
         f"speedup={speedup:.2f}x;admits={plan['counters']['admits']};"
         f"appends={plan['insert_tiers']['appends']}")
    ticks = max(plan["ticks"], 1)
    emit(f"bench_serve/plan_tick_split,"
         f"{plan['device_tick_s'] / ticks * 1e6:.0f},"
         f"device_s={plan['device_tick_s']:.3f};"
         f"host_claim_s={plan['host_claim_s']:.3f};"
         f"claim_us_per_tick={plan['host_claim_s'] / ticks * 1e6:.0f}")
    _claim_bench(emit)

    # ISSUE 6 acceptance gates
    assert plan["counters"]["admits"] == 2 * N_REQ and SLOTS >= 8
    assert plan["decode_traces"] == 1, (
        f"service compiled {plan['decode_traces']} decode kernels across "
        f"{2 * N_REQ} admissions; spec unification promises exactly one")
    assert plan["specs_seen"] == 1, (
        f"{plan['specs_seen']} distinct plan specs across admissions")
    assert speedup >= GATE_SPEEDUP, (
        f"plan-cached service {speedup:.2f}x < {GATE_SPEEDUP}x over the "
        f"per-call Morton-sort decode ({plan['tokens_per_sec']:.1f} vs "
        f"{percall['tokens_per_sec']:.1f} tok/s)")


if __name__ == "__main__":
    run(print)
