"""ClusterKV decode service vs the per-call Morton-sort decode path.

The service thesis: at serving time the cluster ordering of a session's
keys is PLAN STATE, not something to re-derive per token. The per-call
clusterkv decode (``mode="percall"``) re-sorts every slot's cache and
recomputes every centroid inside each decode step; the service
(``mode="plan"``) builds each session's per-layer ``PlanBatch`` once at
admission and insert-streams generated keys into it, so a decode tick is
one scatter + one tile refresh + the sparse attend.

Both modes run the SAME continuous-batching engine over the same request
trace: ``SLOTS`` concurrent sessions with churn (more requests than
slots, mixed prompt lengths, so slots retire and backfill mid-run).

GATES (ISSUE 6): with >= 8 concurrent sessions under churn,
  - service tokens/sec >= 3x the per-call path;
  - the service compiles exactly ONE decode kernel across all admissions
    (trace count asserted, not eyeballed).

  PYTHONPATH=src:. python benchmarks/run.py --only bench_serve
"""
from __future__ import annotations

import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig

SLOTS = 8
N_REQ = 16            # churn: every slot retires + backfills at least once
MAX_SEQ = 8192        # percall pays O(S) sort+permute+centroids per tick;
                      # the service's decode cost is capacity-independent
MAX_NEW = 64
GATE_SPEEDUP = 3.0


def _requests(cfg, rng, rid0=0):
    from repro.train.serve_loop import Request

    lengths = rng.integers(128, 256, size=N_REQ)
    return [Request(rid=rid0 + i,
                    tokens=rng.integers(0, cfg.vocab, int(n)
                                        ).astype(np.int32),
                    max_new=MAX_NEW)
            for i, n in enumerate(lengths)]


def _drive(cfg, params, mode):
    """One long-lived engine per mode: the first request wave warms every
    compile, then the meters reset and a second wave measures steady
    serving. Trace counters span BOTH waves — 2*N_REQ admissions must
    share one decode kernel."""
    from repro.serve import ClusterKVEngine

    engine = ClusterKVEngine(cfg, params, slots=SLOTS, max_seq=MAX_SEQ,
                             prefill_bucket=256, mode=mode)
    rng = np.random.default_rng(0)
    for r in _requests(cfg, rng):
        engine.submit(r)
    engine.run()
    engine.tokens_out, engine._tick_time = 0, 0.0   # keep traces, drop warmup
    for r in _requests(cfg, rng, rid0=N_REQ):
        engine.submit(r)
    engine.run()
    return engine.report()


def run(emit) -> None:
    import jax

    from repro.models import model_api

    # float32: the CPU-performant dtype for BOTH paths (bf16 scatter and
    # gather are emulated elementwise on CPU and would distort the ratio)
    cfg = reduced_config("qwen2-0.5b").with_(
        dtype="float32",
        clusterkv=ClusterKVConfig(enabled=True, block_q=128, block_k=128,
                                  blocks_per_query=4, decode_clusters=4))
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))

    reports = {}
    for mode in ("percall", "plan"):
        _drive(cfg, params, mode)              # warm the compile cache
        reports[mode] = _drive(cfg, params, mode)

    plan, percall = reports["plan"], reports["percall"]
    speedup = plan["tokens_per_sec"] / max(percall["tokens_per_sec"], 1e-9)
    for mode, rep in reports.items():
        us = 1e6 / max(rep["tokens_per_sec"], 1e-9)     # us per token
        emit(f"bench_serve/{mode}_s{SLOTS}_seq{MAX_SEQ},{us:.0f},"
             f"tok_s={rep['tokens_per_sec']:.1f};ticks={rep['ticks']};"
             f"decode_traces={rep['decode_traces']}")
    emit(f"bench_serve/service_speedup,{0:.0f},"
         f"speedup={speedup:.2f}x;admits={plan['counters']['admits']};"
         f"appends={plan['insert_tiers']['appends']}")

    # ISSUE 6 acceptance gates
    assert plan["counters"]["admits"] == 2 * N_REQ and SLOTS >= 8
    assert plan["decode_traces"] == 1, (
        f"service compiled {plan['decode_traces']} decode kernels across "
        f"{2 * N_REQ} admissions; spec unification promises exactly one")
    assert plan["specs_seen"] == 1, (
        f"{plan['specs_seen']} distinct plan specs across admissions")
    assert speedup >= GATE_SPEEDUP, (
        f"plan-cached service {speedup:.2f}x < {GATE_SPEEDUP}x over the "
        f"per-call Morton-sort decode ({plan['tokens_per_sec']:.1f} vs "
        f"{percall['tokens_per_sec']:.1f} tok/s)")


if __name__ == "__main__":
    run(print)
