"""Plan lifecycle: refresh vs rebuild — wall-clock and resulting γ.

Simulates the paper's §3.2 non-stationary loop with two drift shapes:

  coherent   one cluster contracts toward its mode (a real mean-shift
             step: migration is spatially correlated, so the migrated
             rows share a few row-blocks) — the patch tier's home turf,
             and the acceptance scenario: <10% migrated points must
             refresh >=3x faster than a from-scratch ``build_plan`` with
             γ within 5% of a full rebuild
  uniform    every point steps toward its center (migrators spread over
             all row-blocks — the patch tier's worst case; reported, not
             asserted: the win here comes from skipping the O(n^2) kNN,
             not from tile locality)

  PYTHONPATH=src:. python benchmarks/run.py --only bench_refresh
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from repro import api


def _mixture(n: int, d: int, n_clusters: int, seed: int):
    rng = np.random.default_rng(seed)
    basis = rng.standard_normal((n_clusters, d)) / np.sqrt(n_clusters)
    centers = (rng.standard_normal((n_clusters, n_clusters)) @ basis
               * 4.0).astype(np.float32)
    labels = rng.integers(0, n_clusters, n)
    x = (centers[labels] + 0.5 * rng.standard_normal((n, d))
         ).astype(np.float32)
    return x, centers, labels, rng


def _drift(x, centers, labels, rng, shape: str) -> np.ndarray:
    if shape == "coherent":
        # one cluster's mean-shift step: points of cluster 0 contract
        x2 = x.copy()
        sel = labels == 0
        x2[sel] += 0.5 * (centers[0] - x[sel])
        return x2
    # uniform: everyone steps a little (scattered sub-cell motion)
    x2 = x + 0.02 * (centers[labels] - x)
    x2 += 0.003 * rng.standard_normal(x.shape).astype(np.float32)
    return x2


def run(emit) -> None:
    k, n = 16, 4096
    for shape in ("coherent", "uniform"):
        x, centers, labels, rng = _mixture(n, 32, 16, seed=0)
        x2 = _drift(x, centers, labels, rng, shape)
        plan = api.build_plan(x, k=k, bs=32, sb=8, backend="bsr",
                              ell_slack=2)

        t_refresh = timeit(lambda: api.refresh_plan(plan, x2),
                           warmup=1, iters=5)
        t_build = timeit(lambda: api.build_plan(x2, config=plan.config),
                         warmup=1, iters=5)

        refreshed = api.refresh_plan(plan, x2)
        rebuilt = api.build_plan(x2, config=plan.config)
        st = refreshed.refresh_stats
        speedup = t_build / t_refresh
        gamma_ratio = refreshed.gamma / rebuilt.gamma

        emit(f"bench_refresh/{shape}_n{n}_refresh,{t_refresh*1e6:.0f},"
             f"action={st.last_action};migrated={st.last_migrated_frac:.3f}")
        emit(f"bench_refresh/{shape}_n{n}_rebuild,{t_build*1e6:.0f},"
             f"speedup={speedup:.2f}x;gamma_ratio={gamma_ratio:.3f}")

        if shape == "coherent":
            # ISSUE 2 acceptance: <10% migrated -> >=3x faster, γ within 5%
            assert st.last_migrated_frac < 0.10, (
                f"drift scenario migrated {st.last_migrated_frac:.1%} of "
                "points; benchmark is meant to exercise the patch tier")
            assert speedup >= 3.0, (
                f"refresh speedup {speedup:.2f}x < 3x over build_plan")
            assert abs(1.0 - gamma_ratio) <= 0.05, (
                f"refreshed γ {refreshed.gamma:.3f} not within 5% of "
                f"rebuilt γ {rebuilt.gamma:.3f}")


if __name__ == "__main__":
    run(print)
